//! Experiment coordinator: the launcher that ties the stack together.
//!
//! Owns the lifecycle of an experiment: select an execution backend
//! (PJRT artifacts when available, pure-Rust host otherwise — see
//! [`crate::backend::from_env`]) → synthesize the dataset → run each
//! requested weight-handling strategy through the pipelined trainer (the
//! iteration-indexed oracle, or the multi-threaded executor) → aggregate
//! curves, memory accounting and reports. This is the entry point the
//! CLI, the examples and the Fig. 5 bench all share, so every consumer
//! runs the identical code path.

use crate::backend::{self, Backend, Exec};
use crate::config::ExperimentConfig;
use crate::data::{teacher_dataset, Splits};
use crate::metrics::{accuracy_table, write_csv, RunCurve};
use crate::pipeline::PipelinedTrainer;
use crate::strategy::StrategyKind;
use crate::train::Trainer;
use crate::util::Rng;
use anyhow::{Context, Result};

/// Which execution engine a sweep uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecutorKind {
    /// Single-threaded iteration-indexed trainer (the numerical oracle).
    #[default]
    Iteration,
    /// Multi-threaded per-stage pipelined executor (physically overlapped
    /// forward/backward; reproduces the oracle's curves).
    Threaded,
}

/// Results of a full strategy sweep.
#[derive(Debug)]
pub struct SweepResult {
    pub curves: Vec<RunCurve>,
    pub config: ExperimentConfig,
}

impl SweepResult {
    pub fn curve(&self, kind: StrategyKind) -> Option<&RunCurve> {
        self.curves.iter().find(|c| c.strategy == kind.name())
    }

    /// Human-readable comparison table.
    pub fn table(&self) -> String {
        accuracy_table(&self.curves)
    }
}

/// The coordinator: a compiled backend + dataset, reusable across sweeps.
pub struct Coordinator {
    pub backend: Backend,
    pub data: Splits,
    pub cfg: ExperimentConfig,
}

impl Coordinator {
    /// Select the backend and synthesize the dataset for a config.
    pub fn new(cfg: ExperimentConfig) -> Result<Coordinator> {
        cfg.validate()?;
        let backend = backend::from_env(&cfg.artifacts_dir)
            .with_context(|| format!("selecting backend (artifacts: {})", cfg.artifacts_dir))?;
        let data = teacher_dataset(&cfg.model, &cfg.data);
        crate::log_info!(
            "coordinator: backend {}, {} train / {} test samples, {} layers, {} stages",
            backend.name(),
            data.train.len(),
            data.test.len(),
            cfg.model.layers,
            cfg.pipeline.stages
        );
        Ok(Coordinator { backend, data, cfg })
    }

    /// Train one strategy from a fresh, seed-identical initialization.
    ///
    /// Every strategy starts from the same parameters and consumes the
    /// same shuffled batch order (both derived from `cfg.seed`), so the
    /// curves differ only in weight-version handling — the Fig. 5
    /// comparison is apples-to-apples.
    pub fn run_strategy(&self, kind: StrategyKind) -> Result<RunCurve> {
        let mut init_rng = Rng::new(self.cfg.seed);
        let mut trainer = Trainer::new(self.backend.clone(), &self.cfg, kind, &mut init_rng)?;
        let mut batch_rng = Rng::new(self.cfg.seed ^ 0x5EED_BA7C);
        trainer.train(&self.data, &mut batch_rng)
    }

    /// Train one strategy on the multi-threaded pipelined executor, with
    /// the exact seed discipline of [`Coordinator::run_strategy`]. Loss,
    /// accuracy and staleness metrics are interchangeable with the
    /// oracle's; `activation_bytes` uses stage-local accounting and is
    /// not comparable across the two engines.
    pub fn run_strategy_threaded(&self, kind: StrategyKind) -> Result<RunCurve> {
        let mut init_rng = Rng::new(self.cfg.seed);
        let mut ex = PipelinedTrainer::new(self.backend.clone(), &self.cfg, kind, &mut init_rng)?;
        let mut batch_rng = Rng::new(self.cfg.seed ^ 0x5EED_BA7C);
        ex.train(&self.data, &mut batch_rng)
    }

    /// Run the configured strategy sweep (the Fig. 5 experiment) on the
    /// chosen executor.
    pub fn sweep_on(&self, executor: ExecutorKind) -> Result<SweepResult> {
        let mut curves = Vec::with_capacity(self.cfg.strategies.len());
        for &kind in &self.cfg.strategies {
            crate::log_info!("=== strategy: {} ({executor:?}) ===", kind.name());
            let curve = match executor {
                ExecutorKind::Iteration => self.run_strategy(kind)?,
                ExecutorKind::Threaded => self.run_strategy_threaded(kind)?,
            };
            curves.push(curve);
        }
        if let Some(path) = &self.cfg.csv_out {
            write_csv(path, &curves).with_context(|| format!("writing {path}"))?;
            crate::log_info!("wrote {path}");
        }
        Ok(SweepResult { curves, config: self.cfg.clone() })
    }

    /// Run the configured strategy sweep on the iteration-indexed oracle.
    pub fn sweep(&self) -> Result<SweepResult> {
        self.sweep_on(ExecutorKind::Iteration)
    }
}

/// Qualitative Fig. 5 assertions: the orderings the paper reports.
/// Returns a list of human-readable violations (empty = reproduced).
pub fn check_fig5_shape(r: &SweepResult) -> Vec<String> {
    let mut problems = Vec::new();
    let acc = |k: StrategyKind| r.curve(k).map(|c| c.tail_accuracy(3));
    let (Some(seq), Some(stash), Some(latest), Some(pema)) = (
        acc(StrategyKind::Sequential),
        acc(StrategyKind::Stashing),
        acc(StrategyKind::Latest),
        acc(StrategyKind::PipelineAwareEma),
    ) else {
        problems.push("sweep missing required strategies".to_string());
        return problems;
    };
    // (1) Stashing tracks sequential: delayed-but-consistent gradients
    // converge (DLMS). At a fixed finite epoch budget the delayed run
    // trails the undelayed one by up to its pipeline-fill-scaled
    // convergence lag, so allow a modest finite-horizon gap.
    if stash < seq - 0.08 {
        problems.push(format!("stashing {stash:.3} far below sequential {seq:.3}"));
    }
    // (2) Latest-weight degrades relative to stashing.
    if latest > stash + 0.01 {
        problems.push(format!("latest {latest:.3} did not degrade vs stashing {stash:.3}"));
    }
    // (3) The proposed pipeline-aware EMA recovers toward stashing,
    // beating latest.
    if pema < latest - 0.01 {
        problems.push(format!("pipeline EMA {pema:.3} below latest {latest:.3}"));
    }
    if pema < stash - 0.05 {
        problems.push(format!("pipeline EMA {pema:.3} does not track stashing {stash:.3}"));
    }
    // (4) Memory: EMA strategies must use far less staleness state than
    // stashing (the O(LS) → O(L) claim).
    let mem = |k: StrategyKind| r.curve(k).map(|c| c.peak_staleness_bytes());
    if let (Some(ms), Some(me)) = (mem(StrategyKind::Stashing), mem(StrategyKind::PipelineAwareEma))
    {
        if ms == 0 || me * 3 > ms {
            problems.push(format!("memory not reduced: stash {ms} B vs ema {me} B"));
        }
    }
    problems
}
