//! FIG3 bench: per-layer retiming derivation (paper Fig. 3, Eq. 1).
//!
//! Regenerates the per-layer delay table for increasing depths, checks
//! the closed form `Delay(l) = 2·S(l)` and the stepwise==closed-form
//! equivalence at every depth, and times the derivation engine.

use layerpipe2::bench_util::{bench, print_header, print_row, print_table};
use layerpipe2::retiming::{delay_formula, Derivation};
use layerpipe2::schedule::Schedule;
use layerpipe2::retiming::StagePartition;

fn main() {
    // --- per-layer delays across depths (the Fig. 3 structure) ---------
    let mut rows = Vec::new();
    for layers in [3usize, 4, 6, 8, 12] {
        let stage_of: Vec<usize> = (0..layers).collect();
        let d = Derivation::derive(layers, &stage_of).expect("derive");
        d.verify().expect("Eq.1 verification");
        let s = Derivation::derive_stepwise(layers, &stage_of).expect("stepwise");
        assert_eq!(d.gradient_delay, s.gradient_delay, "stepwise == closed form");
        // Cross-check against the schedule simulation (independent path).
        let p = StagePartition::even(layers, layers).unwrap();
        let sched = Schedule::build(&p, 64);
        let observed: Vec<usize> = (0..layers)
            .map(|l| sched.observed_staleness()[p.stage_of()[l]])
            .collect();
        assert_eq!(observed, delay_formula(&stage_of), "schedule agrees");
        rows.push(vec![
            layers.to_string(),
            format!("{:?}", d.gradient_delay),
            format!("{:?}", d.act_stash_depth),
            "yes".into(),
        ]);
    }
    print_table(
        "FIG3: Delay(l)=2S(l) per depth (retiming == stepwise == schedule)",
        &["layers", "gradient delays", "act-stash depths", "verified"],
        &rows,
    );

    // --- timing ---------------------------------------------------------
    print_header("FIG3 timing: derivation engine");
    for layers in [8usize, 32, 128] {
        let stage_of: Vec<usize> = (0..layers).collect();
        let s = bench(&format!("derive_closed_form/L={layers}"), 2, 20, || {
            Derivation::derive(layers, &stage_of).unwrap()
        });
        print_row(&s);
        let s = bench(&format!("derive_stepwise/L={layers}"), 2, 20, || {
            Derivation::derive_stepwise(layers, &stage_of).unwrap()
        });
        print_row(&s);
    }
}
