//! FIG4 bench: grouped multistage pipelining (paper Fig. 4 / §III-C).
//!
//! Regenerates the grouped-stage delay assignments: all layers in a
//! group share one delay, determined by downstream *stages* not layers,
//! across group shapes; and shows the delay/memory tradeoff of grouping.

use layerpipe2::bench_util::{bench, print_header, print_row, print_table};
use layerpipe2::retiming::{Derivation, StagePartition};

fn main() {
    // --- grouped delay assignments (Fig. 4 shape) -----------------------
    let mut rows = Vec::new();
    for (label, sizes) in [
        ("8x1 (per-layer)", vec![1usize; 8]),
        ("4x2 (pairs)", vec![2; 4]),
        ("2x4", vec![4; 2]),
        ("mixed 3+2+2+1", vec![3, 2, 2, 1]),
        ("1x8 (sequential)", vec![8]),
    ] {
        let p = StagePartition::from_group_sizes(&sizes).unwrap();
        let d = Derivation::derive(p.layers(), p.stage_of()).unwrap();
        d.verify().unwrap();
        // Within-group uniformity: the §III-C claim.
        for s in 0..p.stages() {
            let dl: Vec<usize> = p
                .layers_in_stage(s)
                .into_iter()
                .map(|l| d.gradient_delay[l])
                .collect();
            assert!(dl.windows(2).all(|w| w[0] == w[1]), "group {s} delays differ: {dl:?}");
        }
        let total_delay: usize = d.gradient_delay.iter().sum();
        rows.push(vec![
            label.to_string(),
            p.stages().to_string(),
            format!("{:?}", d.gradient_delay),
            total_delay.to_string(),
        ]);
    }
    print_table(
        "FIG4: grouped-stage delays (identical within each group)",
        &["partition", "stages", "per-layer delays", "total stash depth"],
        &rows,
    );

    // --- timing over random partitions ----------------------------------
    print_header("FIG4 timing: derivation over grouped partitions");
    for (name, sizes) in [("4x2", vec![2usize; 4]), ("8x4", vec![4; 8]), ("16x4", vec![4; 16])] {
        let p = StagePartition::from_group_sizes(&sizes).unwrap();
        let stage_of = p.stage_of().to_vec();
        let layers = p.layers();
        let s = bench(&format!("derive_grouped/{name}"), 2, 20, || {
            Derivation::derive(layers, &stage_of).unwrap()
        });
        print_row(&s);
    }
}
