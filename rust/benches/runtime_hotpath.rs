//! HOTPATH bench: L3 runtime overhead on the request path.
//!
//! The perf deliverable's measurement harness: per-artifact dispatch
//! latency (host→literal→execute→host), the full per-layer train
//! iteration, and the fused-vs-chained forward comparison that motivates
//! the `fwd_full` artifact. Requires `make artifacts`.

use layerpipe2::bench_util::{bench, print_header, print_row};
use layerpipe2::config::ExperimentConfig;
use layerpipe2::data::teacher_dataset;
use layerpipe2::model::Mlp;
use layerpipe2::runtime::Engine;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::Tensor;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;

fn main() {
    let engine = Engine::load("artifacts").expect("make artifacts first");
    let m = engine.manifest().model.clone();
    let cfg = layerpipe2::config::ModelConfig {
        batch: m.batch,
        input_dim: m.input_dim,
        hidden_dim: m.hidden_dim,
        classes: m.classes,
        layers: m.layers,
        init_scale: 1.0,
    };
    let mut rng = Rng::new(9);
    let mlp = Mlp::init(&cfg, &mut rng);
    let x = Tensor::randn(&[m.batch, m.input_dim], 1.0, &mut rng);
    let h = Tensor::randn(&[m.batch, m.hidden_dim], 1.0, &mut rng);
    let w = Tensor::randn(&[m.hidden_dim, m.hidden_dim], 0.2, &mut rng);
    let b = Tensor::randn(&[m.hidden_dim], 0.1, &mut rng);
    let dy = Tensor::randn(&[m.batch, m.hidden_dim], 1.0, &mut rng);

    print_header("HOTPATH: single-artifact dispatch latency");
    print_row(&bench("dense_fwd_hid (32x64x64 + bias + relu)", 20, 200, || {
        engine.run("dense_fwd_hid", &[&h, &w, &b]).unwrap()
    }));
    let y = engine.run("dense_fwd_hid", &[&h, &w, &b]).unwrap().remove(0);
    print_row(&bench("dense_bwd_hid (dx,dw,db)", 20, 200, || {
        engine.run("dense_bwd_hid", &[&h, &y, &w, &dy]).unwrap()
    }));
    print_row(&bench("fwd_full (8 layers fused)", 20, 200, || {
        mlp.forward_full(&engine, &x).unwrap()
    }));
    print_row(&bench("fwd chained (8 dispatches)", 20, 200, || {
        let mut hh = x.clone();
        for l in 0..cfg.layers {
            hh = mlp.forward_layer(&engine, l, &hh).unwrap();
        }
        hh
    }));
    // Ablation: the same layer lowered from plain jnp instead of the
    // interpret-mode Pallas kernel — quantifies the interpret-lowering
    // overhead the CPU backend pays for the kernel path (a real-TPU
    // Mosaic build would not).
    if engine.get("ablation_fwd_hid_jnp").is_ok() {
        print_row(&bench("ablation: fwd_hid lowered from jnp", 20, 200, || {
            engine.run("ablation_fwd_hid_jnp", &[&h, &w, &b]).unwrap()
        }));
    }

    print_header("HOTPATH: full pipelined train iteration (8 stages)");
    let mut ecfg = ExperimentConfig::default();
    ecfg.epochs = 1;
    ecfg.data.train_samples = 512;
    ecfg.data.test_samples = 256;
    let data = teacher_dataset(&ecfg.model, &ecfg.data);
    for kind in [
        StrategyKind::Sequential,
        StrategyKind::Stashing,
        StrategyKind::PipelineAwareEma,
    ] {
        let mut trng = Rng::new(1);
        let mut trainer = Trainer::new(&engine, &ecfg, kind, &mut trng).unwrap();
        let (xb, oh) = data.train.batch(&(0..ecfg.model.batch).collect::<Vec<_>>());
        // Prime the pipeline so steady-state iterations do fwd+bwd work.
        for _ in 0..16 {
            trainer.iteration(Some((xb.clone(), oh.clone()))).unwrap();
        }
        let s = bench(&format!("train_iteration/{}", kind.name()), 5, 100, || {
            trainer.iteration(Some((xb.clone(), oh.clone()))).unwrap()
        });
        print_row(&s);
    }

    println!(
        "\nexec count served by engine this run: {} (dispatch bookkeeping works)",
        engine.exec_count()
    );
}
