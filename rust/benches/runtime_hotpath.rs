//! HOTPATH bench: backend dispatch overhead + host kernel throughput.
//!
//! The perf deliverable's measurement harness, in three parts:
//!
//! 1. Host kernel GFLOP/s — the blocked (and, at size, row-parallel)
//!    matmul plus the dense fwd/bwd kernels of the host backend. Runs
//!    everywhere, no artifacts needed.
//! 2. PJRT per-artifact dispatch latency — only when artifacts are
//!    present and the crate was built with `--features pjrt`; skipped
//!    with a note otherwise, so the bench binary stays useful on a
//!    clean checkout.
//! 3. Full pipelined train iterations on whatever backend
//!    `LAYERPIPE2_BACKEND`/auto selects.

use layerpipe2::backend::{self, Exec, HostBackend};
use layerpipe2::bench_util::{bench, print_header, print_row, BenchStats};
use layerpipe2::config::ExperimentConfig;
use layerpipe2::data::teacher_dataset;
use layerpipe2::model::LayerRole;
use layerpipe2::runtime::Engine;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::{self, Tensor};
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;

fn print_gflops(stats: &BenchStats, flops_per_run: f64) {
    print_row(stats);
    println!(
        "    -> {:.2} GFLOP/s (median)",
        flops_per_run / stats.median_s / 1e9
    );
}

fn host_kernel_section() {
    print_header("HOTPATH-a: host kernel GFLOP/s (blocked matmul, row-parallel at size)");
    let mut rng = Rng::new(3);
    for &(m, k, n) in &[(64usize, 64usize, 64usize), (256, 256, 256), (512, 512, 512)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let stats = bench(&format!("host matmul {m}x{k}x{n}"), 3, 30, || {
            tensor::matmul(&a, &b)
        });
        print_gflops(&stats, 2.0 * (m * k * n) as f64);
    }

    let host = HostBackend::new();
    let (bsz, h) = (32usize, 64usize);
    let x = Tensor::randn(&[bsz, h], 1.0, &mut rng);
    let w = Tensor::randn(&[h, h], 0.2, &mut rng);
    let bias = Tensor::randn(&[h], 0.1, &mut rng);
    let dy = Tensor::randn(&[bsz, h], 1.0, &mut rng);
    let y = host.forward(LayerRole::Hidden, &x, &w, &bias).unwrap();
    let fwd_flops = 2.0 * (bsz * h * h) as f64;
    let stats = bench("host dense_fwd_hid (32x64x64 + bias + relu)", 20, 200, || {
        host.forward(LayerRole::Hidden, &x, &w, &bias).unwrap()
    });
    print_gflops(&stats, fwd_flops);
    let stats = bench("host dense_bwd_hid (dx,dw,db)", 20, 200, || {
        host.backward(LayerRole::Hidden, &x, &y, &w, &dy).unwrap()
    });
    print_gflops(&stats, 2.0 * fwd_flops); // dx + dw matmuls dominate
}

fn pjrt_section() {
    print_header("HOTPATH-b: PJRT single-artifact dispatch latency");
    let engine = match Engine::load("artifacts") {
        Ok(e) => e,
        Err(e) => {
            println!("  skipped: {e:#}");
            return;
        }
    };
    let m = engine.manifest().model.clone();
    let mut rng = Rng::new(9);
    let h = Tensor::randn(&[m.batch, m.hidden_dim], 1.0, &mut rng);
    let w = Tensor::randn(&[m.hidden_dim, m.hidden_dim], 0.2, &mut rng);
    let b = Tensor::randn(&[m.hidden_dim], 0.1, &mut rng);
    let dy = Tensor::randn(&[m.batch, m.hidden_dim], 1.0, &mut rng);
    print_row(&bench("pjrt dense_fwd_hid", 20, 200, || {
        engine.run("dense_fwd_hid", &[&h, &w, &b]).unwrap()
    }));
    let y = engine.run("dense_fwd_hid", &[&h, &w, &b]).unwrap().remove(0);
    print_row(&bench("pjrt dense_bwd_hid (dx,dw,db)", 20, 200, || {
        engine.run("dense_bwd_hid", &[&h, &y, &w, &dy]).unwrap()
    }));
    // Ablation: the same layer lowered from plain jnp instead of the
    // interpret-mode Pallas kernel — quantifies the interpret-lowering
    // overhead the CPU backend pays for the kernel path (a real-TPU
    // Mosaic build would not).
    if engine.get("ablation_fwd_hid_jnp").is_ok() {
        print_row(&bench("pjrt ablation: fwd_hid lowered from jnp", 20, 200, || {
            engine.run("ablation_fwd_hid_jnp", &[&h, &w, &b]).unwrap()
        }));
    }
    println!(
        "  exec count served by engine this run: {} (dispatch bookkeeping works)",
        engine.exec_count()
    );
}

fn train_iteration_section() {
    let backend = backend::from_env("artifacts").expect("backend selection");
    print_header(&format!(
        "HOTPATH-c: full pipelined train iteration (8 stages, backend: {})",
        backend.name()
    ));
    let mut ecfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
    ecfg.data.train_samples = 512;
    ecfg.data.test_samples = 256;
    let data = teacher_dataset(&ecfg.model, &ecfg.data);
    for kind in [
        StrategyKind::Sequential,
        StrategyKind::Stashing,
        StrategyKind::PipelineAwareEma,
    ] {
        let mut trng = Rng::new(1);
        let mut trainer = Trainer::new(backend.clone(), &ecfg, kind, &mut trng).unwrap();
        let (xb, oh) = data.train.batch(&(0..ecfg.model.batch).collect::<Vec<_>>());
        // Prime the pipeline so steady-state iterations do fwd+bwd work.
        for _ in 0..16 {
            trainer.iteration(Some((xb.clone(), oh.clone()))).unwrap();
        }
        let s = bench(&format!("train_iteration/{}", kind.name()), 5, 100, || {
            trainer.iteration(Some((xb.clone(), oh.clone()))).unwrap()
        });
        print_row(&s);
    }
    println!(
        "\nexec count served by backend this run: {}",
        backend.exec_count()
    );
}

fn main() {
    host_kernel_section();
    pjrt_section();
    train_iteration_section();
}
