//! HOTPATH bench: backend dispatch overhead + host kernel throughput +
//! hot-path allocation accounting.
//!
//! The perf deliverable's measurement harness, in three parts:
//!
//! 1. Host kernel GFLOP/s — each kernel benched twice: the allocating
//!    form ("before") and the `_into`-reused-buffer form ("after"), with
//!    allocations-per-iteration measured by a counting global allocator.
//! 2. PJRT per-artifact dispatch latency — only when artifacts are
//!    present and the crate was built with `--features pjrt`; skipped
//!    with a note otherwise, so the bench binary stays useful on a
//!    clean checkout.
//! 3. Full pipelined train iterations on whatever backend
//!    `LAYERPIPE2_BACKEND`/auto selects, with steady-state
//!    allocations-per-iteration.
//!
//! Besides the human-readable tables, the run writes machine-readable
//! trajectories: `BENCH_hotpath.json` (dense hot path),
//! `BENCH_layers.json` (layer zoo), `BENCH_kernels.json` (kernel
//! family: scalar reference vs packed/tree kernels, serial vs parallel —
//! with in-run NaN/shape/bit-stability validation, so a kernel
//! regression fails the bench — plus a `mixed_precision` section
//! comparing f32 vs bf16 storage: GB/s, GFLOP/s and max error against
//! the f32 oracle at the dtype-derived bound), `BENCH_serving.json` (batched
//! inference serving: requests/sec + p50/p99 batch latency vs
//! `max_batch`, every response verified bitwise against the sequential
//! oracle in-run), `BENCH_ring.json` (weight-ring replica scaling:
//! samples/sec + scaling efficiency vs replica count, final weights
//! verified bitwise against the single-replica oracle in-run) and
//! `BENCH_observability.json` (span-timing overhead: dense train
//! iteration and serving round-trip with the obs gate off vs on —
//! `verify.sh` gates on the dense overhead staying under 2%). Override
//! paths with `LAYERPIPE2_BENCH_JSON` / `LAYERPIPE2_BENCH_LAYERS_JSON` /
//! `LAYERPIPE2_BENCH_KERNELS_JSON` / `LAYERPIPE2_BENCH_SERVING_JSON` /
//! `LAYERPIPE2_BENCH_RING_JSON` / `LAYERPIPE2_BENCH_OBSERVABILITY_JSON`.
//! Set `LAYERPIPE2_BENCH_SMOKE=1` for a fast CI smoke run (reduced
//! sizes and sample counts, same coverage).

use layerpipe2::backend::{self, Exec, HostBackend};
use layerpipe2::bench_util::{bench, print_header, print_row, BenchStats};
use layerpipe2::config::{ExperimentConfig, ModelConfig};
use layerpipe2::data::teacher_dataset;
use layerpipe2::layers::{Conv2d, Layer, Network, NetworkSpec, SelfAttention};
use layerpipe2::model::LayerRole;
use layerpipe2::obs;
use layerpipe2::pipeline::PipelinedTrainer;
use layerpipe2::replica::{train_ring, RingConfig, RingReport};
use layerpipe2::runtime::Engine;
use layerpipe2::serving::{Server, ServerConfig};
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::{self, Tensor};
use layerpipe2::train::Trainer;
use layerpipe2::util::json::Json;
use layerpipe2::util::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

// ---- counting allocator (allocs/iter metric) --------------------------

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run the warmup outside the counted region (pools and caches reach
/// steady state), then bench while counting heap allocations.
fn bench_counted<T>(
    name: &str,
    warmup: usize,
    samples: usize,
    mut f: impl FnMut() -> T,
) -> (BenchStats, f64) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let stats = bench(name, 0, samples, f);
    let allocs = ALLOCS.load(Ordering::Relaxed) - a0;
    (stats, allocs as f64 / samples as f64)
}

fn smoke() -> bool {
    std::env::var_os("LAYERPIPE2_BENCH_SMOKE").is_some()
}

fn jnum(v: f64) -> Json {
    Json::Num(v)
}

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn print_gflops(stats: &BenchStats, flops_per_run: f64, allocs_per_iter: f64) {
    print_row(stats);
    println!(
        "    -> {:.2} GFLOP/s (median), {allocs_per_iter:.2} allocs/iter",
        flops_per_run / stats.median_s / 1e9
    );
}

fn host_kernel_section(smoke: bool) -> Json {
    print_header("HOTPATH-a: host kernels — allocating (before) vs _into reused buffer (after)");
    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Rng::new(3);
    let sizes: &[(usize, usize, usize)] = if smoke {
        &[(64, 64, 64), (256, 256, 256)]
    } else {
        &[(64, 64, 64), (256, 256, 256), (512, 512, 512)]
    };
    let samples = if smoke { 5 } else { 30 };
    for &(m, k, n) in sizes {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        let (s_alloc, n_alloc) =
            bench_counted(&format!("host matmul {m}x{k}x{n} (alloc)"), 3, samples, || {
                tensor::matmul(&a, &b)
            });
        print_gflops(&s_alloc, flops, n_alloc);
        let mut out = Tensor::empty();
        let (s_into, n_into) =
            bench_counted(&format!("host matmul {m}x{k}x{n} (into)"), 3, samples, || {
                tensor::matmul_into(&a, &b, &mut out)
            });
        print_gflops(&s_into, flops, n_into);
        rows.push(jobj(vec![
            ("case", Json::Str(format!("matmul_{m}x{k}x{n}"))),
            ("gflops_alloc", jnum(flops / s_alloc.median_s / 1e9)),
            ("gflops_into", jnum(flops / s_into.median_s / 1e9)),
            ("ns_per_iter_into", jnum(s_into.median_s * 1e9)),
            ("allocs_per_iter_alloc", jnum(n_alloc)),
            ("allocs_per_iter_into", jnum(n_into)),
        ]));
    }

    let host = HostBackend::new();
    let (bsz, h) = (32usize, 64usize);
    let x = Tensor::randn(&[bsz, h], 1.0, &mut rng);
    let w = Tensor::randn(&[h, h], 0.2, &mut rng);
    let bias = Tensor::randn(&[h], 0.1, &mut rng);
    let dy = Tensor::randn(&[bsz, h], 1.0, &mut rng);
    let y = host.forward(LayerRole::Hidden, &x, &w, &bias).unwrap();
    let fwd_flops = 2.0 * (bsz * h * h) as f64;
    let reps = if smoke { 40 } else { 200 };

    let (s, n_alloc) = bench_counted("host dense_fwd_hid (alloc)", 20, reps, || {
        host.forward(LayerRole::Hidden, &x, &w, &bias).unwrap()
    });
    print_gflops(&s, fwd_flops, n_alloc);
    let mut fwd_out = Tensor::empty();
    let (s_into, n_into) =
        bench_counted("host dense_fwd_hid (into, fused bias+relu)", 20, reps, || {
            host.forward_into(LayerRole::Hidden, &x, &w, &bias, &mut fwd_out).unwrap()
        });
    print_gflops(&s_into, fwd_flops, n_into);
    rows.push(jobj(vec![
        ("case", Json::Str("dense_fwd_hid_32x64x64".to_string())),
        ("gflops_alloc", jnum(fwd_flops / s.median_s / 1e9)),
        ("gflops_into", jnum(fwd_flops / s_into.median_s / 1e9)),
        ("ns_per_iter_into", jnum(s_into.median_s * 1e9)),
        ("allocs_per_iter_alloc", jnum(n_alloc)),
        ("allocs_per_iter_into", jnum(n_into)),
    ]));

    let bwd_flops = 2.0 * fwd_flops; // dx + dw matmuls dominate
    let (s, n_alloc) = bench_counted("host dense_bwd_hid (alloc)", 20, reps, || {
        host.backward(LayerRole::Hidden, &x, &y, &w, &dy).unwrap()
    });
    print_gflops(&s, bwd_flops, n_alloc);
    let (mut scr, mut dxb, mut dwb, mut dbb) =
        (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
    let (s_into, n_into) =
        bench_counted("host dense_bwd_hid (into, fused mask+colsum)", 20, reps, || {
            host.backward_into(
                LayerRole::Hidden,
                &x,
                &y,
                &w,
                &dy,
                &mut scr,
                &mut dxb,
                &mut dwb,
                &mut dbb,
            )
            .unwrap()
        });
    print_gflops(&s_into, bwd_flops, n_into);
    rows.push(jobj(vec![
        ("case", Json::Str("dense_bwd_hid_32x64x64".to_string())),
        ("gflops_alloc", jnum(bwd_flops / s.median_s / 1e9)),
        ("gflops_into", jnum(bwd_flops / s_into.median_s / 1e9)),
        ("ns_per_iter_into", jnum(s_into.median_s * 1e9)),
        ("allocs_per_iter_alloc", jnum(n_alloc)),
        ("allocs_per_iter_into", jnum(n_into)),
    ]));
    Json::Arr(rows)
}

/// HOTPATH-e: conv layer kernels (im2col + pooled matmul) — GFLOP/s and
/// allocs/iter for forward and backward, written to `BENCH_layers.json`
/// so the layer-zoo perf trajectory is tracked separately from the
/// dense hot path.
fn layers_section(smoke: bool) -> Json {
    print_header("HOTPATH-e: conv layer fwd/bwd (im2col + pooled matmul, persistent workspaces)");
    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Rng::new(17);
    // (batch, h, w, in_c, out_c, k): small stays serial; large crosses
    // the worker-pool threshold inside matmul.
    let cases: &[(usize, usize, usize, usize, usize, usize)] = if smoke {
        &[(16, 8, 8, 4, 8, 3), (16, 16, 16, 8, 16, 3)]
    } else {
        &[(16, 8, 8, 4, 8, 3), (16, 16, 16, 8, 16, 3), (32, 32, 32, 16, 32, 3)]
    };
    let samples = if smoke { 5 } else { 30 };
    for &(bsz, h, w, ic, oc, k) in cases {
        let mut op = Conv2d::new(h, w, ic, oc, k, 1, 1, true).unwrap();
        let (wt, b) = op.init_params(1.0, &mut rng);
        let x = Tensor::randn(&[bsz, op.in_dim()], 1.0, &mut rng);
        let be = HostBackend::new();
        let case = format!("conv_{bsz}x{h}x{w}x{ic}->c{oc}k{k}");
        // The op's own cost report — correct for any stride/pad/kernel.
        let cost = op.cost(bsz);
        let fwd_flops = cost.fwd_flops as f64;
        let bwd_flops = cost.bwd_flops as f64;

        let mut y = Tensor::empty();
        let (s_fwd, n_fwd) = bench_counted(&format!("{case} fwd"), 3, samples, || {
            op.forward_into(&be, &x, &wt, &b, &mut y).unwrap()
        });
        print_gflops(&s_fwd, fwd_flops, n_fwd);

        let dy = Tensor::randn(&[bsz, op.out_dim()], 1.0, &mut rng);
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        let (s_bwd, n_bwd) = bench_counted(&format!("{case} bwd"), 3, samples, || {
            op.backward_into(&be, &x, &y, &wt, &dy, &mut scr, &mut dx, &mut dw, &mut db)
                .unwrap()
        });
        print_gflops(&s_bwd, bwd_flops, n_bwd);

        rows.push(jobj(vec![
            ("case", Json::Str(case)),
            ("gflops_fwd", jnum(fwd_flops / s_fwd.median_s / 1e9)),
            ("gflops_bwd", jnum(bwd_flops / s_bwd.median_s / 1e9)),
            ("ns_per_iter_fwd", jnum(s_fwd.median_s * 1e9)),
            ("ns_per_iter_bwd", jnum(s_bwd.median_s * 1e9)),
            ("allocs_per_iter_fwd", jnum(n_fwd)),
            ("allocs_per_iter_bwd", jnum(n_bwd)),
        ]));
    }
    Json::Arr(rows)
}

/// HOTPATH-k: self-attention layer (fused QKV on the pooled matmul +
/// masked softmax + per-sample aggregation) — GFLOP/s and allocs/iter
/// for forward and backward, written to `BENCH_layers.json` next to the
/// conv kernels so the transformer perf trajectory is tracked per PR.
fn attention_section(smoke: bool) -> Json {
    print_header("HOTPATH-k: self-attention fwd/bwd (fused QKV + masked softmax, persistent workspaces)");
    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Rng::new(19);
    // (batch, seq, d_model, causal): small stays serial; large crosses
    // the worker-pool threshold inside the fused projection.
    let cases: &[(usize, usize, usize, bool)] = if smoke {
        &[(16, 16, 32, true), (8, 64, 64, true)]
    } else {
        &[(16, 16, 32, true), (8, 64, 64, true), (8, 128, 128, false)]
    };
    let samples = if smoke { 5 } else { 30 };
    for &(bsz, seq, dm, causal) in cases {
        let mut op = SelfAttention::new(seq, dm, causal).unwrap();
        let (wt, b) = op.init_params(1.0, &mut rng);
        let x = Tensor::randn(&[bsz, op.in_dim()], 1.0, &mut rng);
        let be = HostBackend::new();
        let case = format!(
            "attn_{bsz}x{seq}x{dm}{}",
            if causal { "_causal" } else { "" }
        );
        let cost = op.cost(bsz);
        let fwd_flops = cost.fwd_flops as f64;
        let bwd_flops = cost.bwd_flops as f64;

        let mut y = Tensor::empty();
        let (s_fwd, n_fwd) = bench_counted(&format!("{case} fwd"), 3, samples, || {
            op.forward_into(&be, &x, &wt, &b, &mut y).unwrap()
        });
        print_gflops(&s_fwd, fwd_flops, n_fwd);

        let dy = Tensor::randn(&[bsz, op.out_dim()], 1.0, &mut rng);
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        let (s_bwd, n_bwd) = bench_counted(&format!("{case} bwd"), 3, samples, || {
            op.backward_into(&be, &x, &y, &wt, &dy, &mut scr, &mut dx, &mut dw, &mut db)
                .unwrap()
        });
        print_gflops(&s_bwd, bwd_flops, n_bwd);

        // In-run validation: attention outputs must stay finite (the
        // masked softmax's total-function contract).
        assert!(
            y.data().iter().all(|v| v.is_finite()),
            "{case}: non-finite attention output"
        );

        rows.push(jobj(vec![
            ("case", Json::Str(case)),
            ("gflops_fwd", jnum(fwd_flops / s_fwd.median_s / 1e9)),
            ("gflops_bwd", jnum(bwd_flops / s_bwd.median_s / 1e9)),
            ("ns_per_iter_fwd", jnum(s_fwd.median_s * 1e9)),
            ("ns_per_iter_bwd", jnum(s_bwd.median_s * 1e9)),
            ("allocs_per_iter_fwd", jnum(n_fwd)),
            ("allocs_per_iter_bwd", jnum(n_bwd)),
        ]));
    }
    Json::Arr(rows)
}

/// HOTPATH-f: the kernel family, serial scalar reference ("before") vs
/// the tiled kernel on one worker vs the tiled kernel on the pool
/// ("after") — GFLOP/s per kernel per shape, written to
/// `BENCH_kernels.json`. Every variant's output is validated in-run:
/// shapes must match, no NaN/non-finite values, the packed matmul/nt
/// must be bitwise equal to the reference, the tree-reduction tn must be
/// bit-stable across worker counts and close to the sequential
/// reference — a silent kernel regression fails the bench (and
/// `verify.sh`, which runs it in smoke mode).
fn kernel_family_section(smoke: bool) -> Json {
    print_header(&format!(
        "HOTPATH-f: kernel family — scalar reference vs tiled/tree (pool: {} workers)",
        layerpipe2::tensor::workers::pool_size()
    ));
    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Rng::new(23);
    let samples = if smoke { 5 } else { 20 };
    let workers = layerpipe2::tensor::workers::pool_size() as f64;

    let check = |name: &str, out: &Tensor, want_shape: &[usize]| {
        assert_eq!(out.shape(), want_shape, "{name}: output shape mismatch");
        assert!(
            out.data().iter().all(|v| v.is_finite()),
            "{name}: non-finite values in kernel output"
        );
    };

    // ---- matmul / matmul_nt: C = A·B and A·Bᵀ --------------------------
    let mm_cases: &[(usize, usize, usize)] = if smoke {
        &[(192, 192, 192)]
    } else {
        &[(256, 256, 256), (512, 512, 512)]
    };
    for &(m, k, n) in mm_cases {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;

        for (kernel, reference, run_1t, run_par) in [
            (
                "matmul",
                tensor::reference::matmul(&a, &b),
                {
                    let mut o = Tensor::empty();
                    tensor::matmul_into_with_threads(&a, &b, &mut o, 1);
                    o
                },
                {
                    let mut o = Tensor::empty();
                    tensor::matmul_into(&a, &b, &mut o);
                    o
                },
            ),
            (
                "matmul_nt",
                tensor::reference::matmul_nt(&a, &bt),
                {
                    let mut o = Tensor::empty();
                    tensor::matmul_nt_into_with_threads(&a, &bt, &mut o, 1);
                    o
                },
                {
                    let mut o = Tensor::empty();
                    tensor::matmul_nt_into(&a, &bt, &mut o);
                    o
                },
            ),
        ] {
            let case = format!("{kernel}_{m}x{k}x{n}");
            check(&case, &reference, &[m, n]);
            check(&case, &run_1t, &[m, n]);
            check(&case, &run_par, &[m, n]);
            assert_eq!(run_1t, reference, "{case}: tiled kernel not bitwise vs reference");
            assert_eq!(run_par, run_1t, "{case}: parallel split changed the bits");

            let s_ref = bench(&format!("{case} (serial reference)"), 2, samples, || {
                if kernel == "matmul" {
                    tensor::reference::matmul(&a, &b)
                } else {
                    tensor::reference::matmul_nt(&a, &bt)
                }
            });
            print_gflops(&s_ref, flops, 0.0);
            let mut out = Tensor::empty();
            let s_1t = bench(&format!("{case} (packed, 1 worker)"), 2, samples, || {
                if kernel == "matmul" {
                    tensor::matmul_into_with_threads(&a, &b, &mut out, 1)
                } else {
                    tensor::matmul_nt_into_with_threads(&a, &bt, &mut out, 1)
                }
            });
            print_gflops(&s_1t, flops, 0.0);
            let s_par = bench(&format!("{case} (packed, pool)"), 2, samples, || {
                if kernel == "matmul" {
                    tensor::matmul_into(&a, &b, &mut out)
                } else {
                    tensor::matmul_nt_into(&a, &bt, &mut out)
                }
            });
            print_gflops(&s_par, flops, 0.0);
            rows.push(jobj(vec![
                ("kernel", Json::Str(kernel.to_string())),
                ("case", Json::Str(case)),
                ("gflops_serial", jnum(flops / s_ref.median_s / 1e9)),
                ("gflops_1w", jnum(flops / s_1t.median_s / 1e9)),
                ("gflops_parallel", jnum(flops / s_par.median_s / 1e9)),
                ("workers", jnum(workers)),
            ]));
        }
    }

    // ---- matmul_tn: the dw reduction, serial vs deterministic tree -----
    // (r, m, n): dense-like tall-r shapes plus a conv-im2col-like one.
    let tn_cases: &[(usize, usize, usize)] = if smoke {
        &[(1024, 128, 128)]
    } else {
        &[(2048, 256, 256), (4096, 72, 64)]
    };
    for &(r, m, n) in tn_cases {
        let a = Tensor::randn(&[r, m], 0.5, &mut rng);
        let b = Tensor::randn(&[r, n], 0.5, &mut rng);
        let flops = 2.0 * (r * m * n) as f64;
        let case = format!("matmul_tn_{r}x{m}x{n}");

        let reference = tensor::reference::matmul_tn(&a, &b);
        check(&case, &reference, &[m, n]);
        let mut t1 = Tensor::empty();
        tensor::matmul_tn_into_with_threads(&a, &b, &mut t1, 1);
        check(&case, &t1, &[m, n]);
        let mut tp = Tensor::empty();
        tensor::matmul_tn_into(&a, &b, &mut tp);
        check(&case, &tp, &[m, n]);
        assert_eq!(tp, t1, "{case}: tree reduction not bit-stable across worker counts");
        let drift = tp.max_abs_diff(&reference) / (r as f32).sqrt();
        assert!(
            drift < 1e-4,
            "{case}: tree reduction drifted from sequential reference ({drift})"
        );

        let s_ref = bench(&format!("{case} (serial reference)"), 2, samples, || {
            tensor::reference::matmul_tn(&a, &b)
        });
        print_gflops(&s_ref, flops, 0.0);
        let mut out = Tensor::empty();
        let s_1t = bench(&format!("{case} (tree, 1 worker)"), 2, samples, || {
            tensor::matmul_tn_into_with_threads(&a, &b, &mut out, 1)
        });
        print_gflops(&s_1t, flops, 0.0);
        let s_par = bench(&format!("{case} (tree, pool)"), 2, samples, || {
            tensor::matmul_tn_into(&a, &b, &mut out)
        });
        print_gflops(&s_par, flops, 0.0);
        let speedup = s_ref.median_s / s_par.median_s;
        println!("    -> dw parallel speedup vs serial reference: {speedup:.2}x");
        rows.push(jobj(vec![
            ("kernel", Json::Str("matmul_tn".to_string())),
            ("case", Json::Str(case)),
            ("gflops_serial", jnum(flops / s_ref.median_s / 1e9)),
            ("gflops_1w", jnum(flops / s_1t.median_s / 1e9)),
            ("gflops_parallel", jnum(flops / s_par.median_s / 1e9)),
            ("dw_speedup_vs_serial", jnum(speedup)),
            ("workers", jnum(workers)),
        ]));
    }
    Json::Arr(rows)
}

/// HOTPATH-i: mixed precision — the packed matmul on bf16 storage vs
/// the same kernel on f32, plus the quantize/widen conversion kernels,
/// written into `BENCH_kernels.json` under `"mixed_precision"` (which
/// `verify.sh` gates on). Per shape the section reports GFLOP/s and
/// effective GB/s of storage traffic (bf16 halves the operand bytes;
/// the f32 output is unchanged), and validates the DESIGN.md §11
/// contract in-run: the bf16-input kernel must be **bitwise** equal to
/// the f32 kernel run on pre-widened copies of the same operands
/// (widening-on-pack: summation geometry is a pure function of shape),
/// and its error against the unquantized f32 oracle must respect the
/// dtype-derived per-element bound `eps_bf16 · Σ_k |a_ik|·|b_kj|`.
fn mixed_precision_section(smoke: bool) -> Json {
    use layerpipe2::tensor::{Dtype, EPS_BF16};
    print_header("HOTPATH-i: mixed precision — f32 vs bf16 storage matmul (widen-on-pack)");
    let mut rows: Vec<Json> = Vec::new();
    let mut rng = Rng::new(41);
    let samples = if smoke { 5 } else { 20 };
    let workers = layerpipe2::tensor::workers::pool_size() as f64;

    let mm_cases: &[(usize, usize, usize)] = if smoke {
        &[(192, 192, 192)]
    } else {
        &[(256, 256, 256), (512, 512, 512)]
    };
    for &(m, k, n) in mm_cases {
        let af = Tensor::randn(&[m, k], 1.0, &mut rng);
        let bf = Tensor::randn(&[k, n], 1.0, &mut rng);
        let ab = af.to_dtype(Dtype::Bf16);
        let bb = bf.to_dtype(Dtype::Bf16);
        let flops = 2.0 * (m * k * n) as f64;
        // Storage traffic per run: both operands read once, f32 output
        // written once — the quantity the bf16 panels actually halve.
        let bytes_f32 = ((m * k + k * n) * 4 + m * n * 4) as f64;
        let bytes_bf16 = ((m * k + k * n) * 2 + m * n * 4) as f64;

        // Widening-on-pack determinism gate: bf16 inputs vs pre-widened
        // f32 copies of the same (quantized) values must be bitwise.
        let mut out_bf = Tensor::empty();
        tensor::matmul_into(&ab, &bb, &mut out_bf);
        let mut out_widened = Tensor::empty();
        tensor::matmul_into(&ab.to_dtype(Dtype::F32), &bb.to_dtype(Dtype::F32), &mut out_widened);
        assert_eq!(
            out_bf, out_widened,
            "matmul_{m}x{k}x{n}: bf16 kernel not bitwise vs widened-f32 kernel"
        );

        // Accuracy gate vs the unquantized f32 oracle, per element at
        // the dtype-derived tolerance: input RTNE carries relative
        // error <= eps_bf16/2 per operand, so the length-k reduction is
        // bounded by eps_bf16 · Σ|a||b| (1.05 covers the cross terms
        // and the f32 accumulation difference; +1e-6 floors it for
        // cancellation-heavy elements).
        let mut oracle = Tensor::empty();
        tensor::matmul_into(&af, &bf, &mut oracle);
        let abs_a =
            Tensor::from_vec(&[m, k], af.data().iter().map(|v| v.abs()).collect());
        let abs_b =
            Tensor::from_vec(&[k, n], bf.data().iter().map(|v| v.abs()).collect());
        let mut abs_mm = Tensor::empty();
        tensor::matmul_into(&abs_a, &abs_b, &mut abs_mm);
        let mut max_err = 0.0f32;
        let mut max_ratio = 0.0f32;
        for ((&got, &want), &bound) in
            out_bf.data().iter().zip(oracle.data()).zip(abs_mm.data())
        {
            let err = (got - want).abs();
            let tol = 1.05 * EPS_BF16 * bound + 1e-6;
            assert!(
                err <= tol,
                "matmul_{m}x{k}x{n}: bf16 error {err} beyond dtype-derived bound {tol}"
            );
            max_err = max_err.max(err);
            max_ratio = max_ratio.max(err / tol);
        }

        let mut out = Tensor::empty();
        let s_f32 = bench(&format!("matmul_{m}x{k}x{n} (f32 storage)"), 2, samples, || {
            tensor::matmul_into(&af, &bf, &mut out)
        });
        print_gflops(&s_f32, flops, 0.0);
        let s_bf16 = bench(&format!("matmul_{m}x{k}x{n} (bf16 storage)"), 2, samples, || {
            tensor::matmul_into(&ab, &bb, &mut out)
        });
        print_gflops(&s_bf16, flops, 0.0);
        println!(
            "    -> storage traffic {:.2} GB/s (f32) vs {:.2} GB/s effective (bf16), \
             max |err| vs f32 oracle {max_err:.3e} ({:.0}% of dtype bound)",
            bytes_f32 / s_f32.median_s / 1e9,
            bytes_bf16 / s_bf16.median_s / 1e9,
            max_ratio * 100.0
        );
        rows.push(jobj(vec![
            ("kernel", Json::Str("matmul".to_string())),
            ("case", Json::Str(format!("mixed_matmul_{m}x{k}x{n}"))),
            ("gflops_f32", jnum(flops / s_f32.median_s / 1e9)),
            ("gflops_bf16", jnum(flops / s_bf16.median_s / 1e9)),
            ("gbps_f32", jnum(bytes_f32 / s_f32.median_s / 1e9)),
            ("gbps_bf16", jnum(bytes_bf16 / s_bf16.median_s / 1e9)),
            ("max_abs_err_vs_f32", jnum(max_err as f64)),
            ("err_over_dtype_bound", jnum(max_ratio as f64)),
            ("workers", jnum(workers)),
        ]));
    }

    // The conversion kernels themselves: quantize (f32 -> bf16, 6 bytes
    // moved per element) and widen (bf16 -> f32, same traffic) — these
    // sit on every optimizer step and every ring flatten/scatter.
    let len = if smoke { 1 << 18 } else { 1 << 22 };
    let src = Tensor::randn(&[len], 1.0, &mut rng);
    let mut q = Tensor::empty();
    let s_q = bench("quantize f32->bf16", 2, samples, || q.quantize_from(&src));
    print_row(&s_q);
    let mut wide = Tensor::empty();
    let s_w = bench("widen bf16->f32", 2, samples, || wide.widen_from(&q));
    print_row(&s_w);
    let conv_bytes = (len * (4 + 2)) as f64;
    println!(
        "    -> quantize {:.2} GB/s, widen {:.2} GB/s ({len} elements)",
        conv_bytes / s_q.median_s / 1e9,
        conv_bytes / s_w.median_s / 1e9
    );
    rows.push(jobj(vec![
        ("kernel", Json::Str("convert".to_string())),
        ("case", Json::Str(format!("convert_{len}"))),
        ("gbps_quantize", jnum(conv_bytes / s_q.median_s / 1e9)),
        ("gbps_widen", jnum(conv_bytes / s_w.median_s / 1e9)),
        ("workers", jnum(workers)),
    ]));
    Json::Arr(rows)
}

fn pjrt_section() {
    print_header("HOTPATH-b: PJRT single-artifact dispatch latency");
    let engine = match Engine::load("artifacts") {
        Ok(e) => e,
        Err(e) => {
            println!("  skipped: {e:#}");
            return;
        }
    };
    let m = engine.manifest().model.clone();
    let mut rng = Rng::new(9);
    let h = Tensor::randn(&[m.batch, m.hidden_dim], 1.0, &mut rng);
    let w = Tensor::randn(&[m.hidden_dim, m.hidden_dim], 0.2, &mut rng);
    let b = Tensor::randn(&[m.hidden_dim], 0.1, &mut rng);
    let dy = Tensor::randn(&[m.batch, m.hidden_dim], 1.0, &mut rng);
    print_row(&bench("pjrt dense_fwd_hid", 20, 200, || {
        engine.run("dense_fwd_hid", &[&h, &w, &b]).unwrap()
    }));
    let y = engine.run("dense_fwd_hid", &[&h, &w, &b]).unwrap().remove(0);
    print_row(&bench("pjrt dense_bwd_hid (dx,dw,db)", 20, 200, || {
        engine.run("dense_bwd_hid", &[&h, &y, &w, &dy]).unwrap()
    }));
    // Ablation: the same layer lowered from plain jnp instead of the
    // interpret-mode Pallas kernel — quantifies the interpret-lowering
    // overhead the CPU backend pays for the kernel path (a real-TPU
    // Mosaic build would not).
    if engine.get("ablation_fwd_hid_jnp").is_ok() {
        print_row(&bench("pjrt ablation: fwd_hid lowered from jnp", 20, 200, || {
            engine.run("ablation_fwd_hid_jnp", &[&h, &w, &b]).unwrap()
        }));
    }
    println!(
        "  exec count served by engine this run: {} (dispatch bookkeeping works)",
        engine.exec_count()
    );
}

fn train_iteration_section(smoke: bool) -> Json {
    let backend = backend::from_env("artifacts").expect("backend selection");
    print_header(&format!(
        "HOTPATH-c: pipelined train iteration (iteration-indexed oracle, 8-stage delays, backend: {})",
        backend.name()
    ));
    let mut ecfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
    ecfg.data.train_samples = 512;
    ecfg.data.test_samples = 256;
    let data = teacher_dataset(&ecfg.model, &ecfg.data);
    let mut rows: Vec<Json> = Vec::new();
    let (warmup, reps) = if smoke { (3, 20) } else { (5, 100) };
    for kind in [
        StrategyKind::Sequential,
        StrategyKind::Stashing,
        StrategyKind::PipelineAwareEma,
    ] {
        let mut trng = Rng::new(1);
        let mut trainer = Trainer::new(backend.clone(), &ecfg, kind, &mut trng).unwrap();
        let (xb, oh) = data.train.batch(&(0..ecfg.model.batch).collect::<Vec<_>>());
        // Prime the pipeline past the deepest delay so steady-state
        // iterations do fwd+bwd work on warmed pools.
        for _ in 0..32 {
            trainer.iteration(Some((xb.clone(), oh.clone()))).unwrap();
        }
        // Batches are cloned outside the counted region: feeding data is
        // the loader's cost, not the iteration's.
        let mut feed: Vec<(Tensor, Tensor)> =
            (0..(warmup + reps)).map(|_| (xb.clone(), oh.clone())).collect();
        feed.reverse();
        let (s, allocs) =
            bench_counted(&format!("train_iteration/{}", kind.name()), warmup, reps, || {
                trainer.iteration(Some(feed.pop().expect("prefed batch"))).unwrap()
            });
        print_row(&s);
        println!("    -> {allocs:.2} allocs/iter (steady state)");
        rows.push(jobj(vec![
            ("strategy", Json::Str(kind.name().to_string())),
            ("ns_per_iter", jnum(s.median_s * 1e9)),
            ("allocs_per_iter", jnum(allocs)),
        ]));
    }
    println!(
        "\nexec count served by backend this run: {}",
        backend.exec_count()
    );
    Json::Arr(rows)
}

fn executor_pool_section(smoke: bool) -> Json {
    let backend = backend::from_env("artifacts").expect("backend selection");
    print_header(&format!(
        "HOTPATH-d: threaded executor stage-pool reuse (8 stages, backend: {})",
        backend.name()
    ));
    let mut ecfg = ExperimentConfig { epochs: if smoke { 1 } else { 2 }, ..ExperimentConfig::default() };
    ecfg.data.train_samples = if smoke { 128 } else { 256 };
    ecfg.data.test_samples = 64;
    let data = teacher_dataset(&ecfg.model, &ecfg.data);
    let mut rng = Rng::new(1);
    let mut ex =
        PipelinedTrainer::new(backend, &ecfg, StrategyKind::PipelineAwareEma, &mut rng).unwrap();
    let mut batch_rng = Rng::new(5);
    ex.train(&data, &mut batch_rng).expect("executor train");
    let (hits, misses) = ex.pool_stats();
    let served = hits as f64 * 100.0 / (hits + misses).max(1) as f64;
    println!(
        "  stage-pool takes: {hits} hits / {misses} misses ({served:.1}% served from recycled buffers)"
    );
    jobj(vec![
        ("pool_hits", jnum(hits as f64)),
        ("pool_misses", jnum(misses as f64)),
        ("pool_served_pct", jnum(served)),
    ])
}

/// HOTPATH-g: batched inference serving — requests/sec, rows/sec and
/// p50/p99 batch latency as a function of `max_batch`, written to
/// `BENCH_serving.json` so the serving perf trajectory is tracked across
/// PRs. Every response is verified bitwise against the sequential
/// forward oracle in-run, so a serving correctness regression fails the
/// bench (and `verify.sh`, which runs it in smoke mode).
fn serving_section(smoke: bool) -> Json {
    print_header("HOTPATH-g: batched inference serving (dense stack, 2 stages, 2 clients)");
    let mut rows_out: Vec<Json> = Vec::new();
    let mcfg = ModelConfig {
        batch: 32,
        input_dim: 64,
        hidden_dim: 64,
        classes: 10,
        layers: 4,
        init_scale: 1.0,
    };
    let net = Network::build(&NetworkSpec::mlp(&mcfg), &mut Rng::new(31)).unwrap();
    let be = HostBackend::new();
    let mut oracle = net.snapshot().unwrap();

    let batch_sizes: &[usize] = if smoke { &[4, 16] } else { &[1, 8, 32] };
    let n_clients = 2usize;
    let per_client = if smoke { 200 } else { 2000 };
    for &mb in batch_sizes {
        let server = Server::start(
            Arc::new(HostBackend::new()),
            &net,
            &ServerConfig {
                max_batch: mb,
                max_wait_ticks: 2,
                queue_depth: 64,
                stages: 2,
                ..ServerConfig::default()
            },
        )
        .expect("server start");
        let req_rows = (mb / 2).max(1);
        let inputs = vec![Tensor::randn(&[req_rows, mcfg.input_dim], 1.0, &mut Rng::new(7))];
        let expected = vec![vec![oracle.forward_full(&be, &inputs[0]).unwrap()]];

        let sw = std::time::Instant::now();
        std::thread::scope(|s| {
            let inputs = &inputs;
            let expected = &expected;
            for _ in 0..n_clients {
                let mut cl = server.client();
                s.spawn(move || {
                    // In-run correctness gate: every response bitwise ==
                    // the sequential oracle, in FIFO order (window 8).
                    layerpipe2::serving::drive_and_verify(&mut cl, inputs, expected, |_| 0, per_client, 8)
                        .expect("serving bench responses must match the sequential oracle");
                });
            }
        });
        let elapsed = sw.elapsed().as_secs_f64();
        let total = (n_clients * per_client) as f64;
        let (p50, p99) = server.latency_ms().unwrap_or((0.0, 0.0));
        let stats = server.shutdown().expect("shutdown");
        assert_eq!(stats.completed, total as u64, "serving dropped responses");
        println!(
            "  max_batch {mb:>3}: {:>9.0} req/s {:>10.0} rows/s  batch p50 {p50:.3}ms p99 {p99:.3}ms  \
             occupancy {:.2} ({} batches)",
            total / elapsed,
            total * req_rows as f64 / elapsed,
            stats.occupancy,
            stats.batches
        );
        rows_out.push(jobj(vec![
            ("case", Json::Str(format!("serve_b{mb}"))),
            ("max_batch", jnum(mb as f64)),
            ("req_rows", jnum(req_rows as f64)),
            ("requests_per_sec", jnum(total / elapsed)),
            ("rows_per_sec", jnum(total * req_rows as f64 / elapsed)),
            ("batch_p50_ms", jnum(p50)),
            ("batch_p99_ms", jnum(p99)),
            ("occupancy", jnum(stats.occupancy)),
            ("batches", jnum(stats.batches as f64)),
            ("pool_hits", jnum(stats.pool_hits as f64)),
            ("pool_misses", jnum(stats.pool_misses as f64)),
        ]));
    }
    Json::Arr(rows_out)
}

/// HOTPATH-g2: AIMD adaptive batching — the same serving workload with
/// the p99-driven controller on, against an aggressive latency target so
/// the backoff path actually runs. Written into `BENCH_serving.json`
/// under `"adaptive"` (gated by `verify.sh`). Responses stay verified
/// bitwise against the sequential oracle — the controller only moves
/// batch-formation limits, never payloads — and the final limits must
/// sit inside the configured clamps.
fn adaptive_section(smoke: bool) -> Json {
    print_header("HOTPATH-g2: AIMD adaptive batching (p99-driven limits, oracle-verified)");
    let mcfg = ModelConfig {
        batch: 32,
        input_dim: 64,
        hidden_dim: 64,
        classes: 10,
        layers: 4,
        init_scale: 1.0,
    };
    let net = Network::build(&NetworkSpec::mlp(&mcfg), &mut Rng::new(31)).unwrap();
    let be = HostBackend::new();
    let mut oracle = net.snapshot().unwrap();
    let cfg = ServerConfig {
        max_batch: 16,
        max_wait_ticks: 4,
        queue_depth: 64,
        stages: 2,
        adaptive: true,
        // Aggressive target: steady traffic overshoots it, so the
        // multiplicative-decrease path is exercised, not just idled.
        adapt_target_p99_ms: 0.05,
        adapt_min_batch: 2,
        adapt_min_wait_ticks: 0,
        ..ServerConfig::default()
    };
    let server = Server::start(Arc::new(HostBackend::new()), &net, &cfg).expect("server start");
    let inputs = vec![Tensor::randn(&[4, mcfg.input_dim], 1.0, &mut Rng::new(7))];
    let expected = vec![vec![oracle.forward_full(&be, &inputs[0]).unwrap()]];
    let n_clients = 2usize;
    let per_client = if smoke { 200 } else { 2000 };

    let sw = std::time::Instant::now();
    std::thread::scope(|s| {
        let inputs = &inputs;
        let expected = &expected;
        for _ in 0..n_clients {
            let mut cl = server.client();
            s.spawn(move || {
                layerpipe2::serving::drive_and_verify(&mut cl, inputs, expected, |_| 0, per_client, 8)
                    .expect("adaptive serving must stay bitwise == the sequential oracle");
            });
        }
    });
    let elapsed = sw.elapsed().as_secs_f64();
    let total = (n_clients * per_client) as f64;
    let (p50, p99) = server.latency_ms().unwrap_or((0.0, 0.0));
    let (fin_batch, fin_wait) =
        server.adaptive_limits().expect("adaptive server must expose its limits");
    assert!(
        (cfg.adapt_min_batch..=cfg.max_batch).contains(&fin_batch)
            && (cfg.adapt_min_wait_ticks..=cfg.max_wait_ticks).contains(&fin_wait),
        "adaptive limits ({fin_batch}, {fin_wait}) escaped the configured clamps"
    );
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.completed, total as u64, "adaptive serving dropped responses");
    println!(
        "  adaptive: {:>9.0} req/s  batch p50 {p50:.3}ms p99 {p99:.3}ms  \
         final limits (max_batch {fin_batch}, max_wait_ticks {fin_wait}) \
         within [{}..={}] x [{}..={}]",
        total / elapsed,
        cfg.adapt_min_batch,
        cfg.max_batch,
        cfg.adapt_min_wait_ticks,
        cfg.max_wait_ticks
    );
    jobj(vec![
        ("requests_per_sec", jnum(total / elapsed)),
        ("batch_p50_ms", jnum(p50)),
        ("batch_p99_ms", jnum(p99)),
        ("target_p99_ms", jnum(cfg.adapt_target_p99_ms)),
        ("final_max_batch", jnum(fin_batch as f64)),
        ("final_max_wait_ticks", jnum(fin_wait as f64)),
        ("min_batch", jnum(cfg.adapt_min_batch as f64)),
        ("max_batch", jnum(cfg.max_batch as f64)),
        ("min_wait_ticks", jnum(cfg.adapt_min_wait_ticks as f64)),
        ("max_wait_ticks", jnum(cfg.max_wait_ticks as f64)),
        ("batches", jnum(stats.batches as f64)),
    ])
}

/// HOTPATH-h: weight-ring replica scaling — samples/sec and scaling
/// efficiency as a function of the replica count on a fixed shard
/// decomposition, written to `BENCH_ring.json` so the 2D (pipeline ×
/// data) training trajectory is tracked across PRs. The final weights
/// of every replica count are compared bitwise against the
/// single-replica oracle in-run, so a determinism regression in the
/// all-reduce fails the bench (and `verify.sh`, which runs it in smoke
/// mode).
fn ring_section(smoke: bool) -> Json {
    print_header("HOTPATH-h: weight-ring replica scaling (fixed shards, deterministic all-reduce)");
    let mut rows_out: Vec<Json> = Vec::new();
    let mut ecfg = ExperimentConfig { epochs: if smoke { 1 } else { 2 }, ..ExperimentConfig::default() };
    ecfg.model.batch = if smoke { 64 } else { 128 };
    ecfg.model.input_dim = 64;
    ecfg.model.hidden_dim = if smoke { 64 } else { 128 };
    ecfg.model.classes = 10;
    ecfg.model.layers = 4;
    ecfg.pipeline.stages = 2;
    ecfg.data.train_samples = if smoke { 256 } else { 2048 };
    ecfg.data.test_samples = if smoke { 64 } else { 256 };
    let data = teacher_dataset(&ecfg.model, &ecfg.data);
    let shards = 8usize;
    let kind = StrategyKind::PipelineAwareEma;
    let backend = backend::from_env("artifacts").expect("backend selection");

    let bitwise_eq = |a: &RingReport, b: &RingReport| {
        a.final_weights.len() == b.final_weights.len()
            && a.final_weights
                .data()
                .iter()
                .zip(b.final_weights.data())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    };

    let mut oracle: Option<RingReport> = None;
    for replicas in [1usize, 2, 4] {
        let ring = RingConfig::new(replicas, shards);
        let report =
            train_ring(&backend, &ecfg, None, kind, &ring, &data).expect("ring training runs");
        let base_sps = oracle.as_ref().map_or(report.samples_per_sec, |o| o.samples_per_sec);
        let speedup = report.samples_per_sec / base_sps;
        let efficiency = speedup / replicas as f64;
        println!(
            "  replicas {replicas}: {:>9.1} samples/s  speedup {speedup:.2}x  efficiency {:.2}  \
             ({} iterations, loss {:.4})",
            report.samples_per_sec,
            efficiency,
            report.iterations,
            report.train_loss
        );
        if let Some(o) = &oracle {
            // In-run determinism gate: any drift in the all-reduce is a
            // bench failure, not just a perf regression.
            assert!(
                bitwise_eq(&report, o),
                "ring final weights at {replicas} replicas differ from the single-replica oracle"
            );
        }
        rows_out.push(jobj(vec![
            ("case", Json::Str(format!("ring_r{replicas}_s{shards}"))),
            ("replicas", jnum(replicas as f64)),
            ("shards", jnum(shards as f64)),
            ("iterations", jnum(report.iterations as f64)),
            ("samples_per_sec", jnum(report.samples_per_sec)),
            ("speedup_vs_1", jnum(speedup)),
            ("scaling_efficiency", jnum(efficiency)),
            ("train_loss", jnum(report.train_loss as f64)),
            ("test_accuracy", jnum(report.test_accuracy as f64)),
        ]));
        if oracle.is_none() {
            oracle = Some(report);
        }
    }
    println!("  final weights bitwise identical across all replica counts");
    Json::Arr(rows_out)
}

/// HOTPATH-j: observability overhead — the dense train iteration and the
/// serving round-trip benched with span timing off vs on
/// ([`obs::set_enabled`]; counters are always on in both modes, the gate
/// covers only the clock-reading spans). Alternating passes with
/// best-of-medians per mode, so a slow outlier pass can't fake an
/// overhead. Gate: the obs-on dense hot path must stay within 2% of
/// obs-off (`"gate_ok"`, checked by `verify.sh`). Written to
/// `BENCH_observability.json` together with the process-wide telemetry
/// snapshot, so the instrument inventory rides along with the numbers.
fn observability_section(smoke: bool) -> Json {
    print_header("HOTPATH-j: observability overhead — span gate off vs on (dense + serving)");

    // Dense: same workload as HOTPATH-c (PipelineAwareEma iteration).
    let backend = backend::from_env("artifacts").expect("backend selection");
    let mut ecfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
    ecfg.data.train_samples = 512;
    ecfg.data.test_samples = 256;
    let data = teacher_dataset(&ecfg.model, &ecfg.data);
    let (warmup, reps) = if smoke { (3, 20) } else { (5, 100) };
    let passes = if smoke { 1 } else { 2 };

    let mut dense_pass = |on: bool| -> f64 {
        obs::set_enabled(on);
        let mut trng = Rng::new(1);
        let mut trainer =
            Trainer::new(backend.clone(), &ecfg, StrategyKind::PipelineAwareEma, &mut trng)
                .unwrap();
        let (xb, oh) = data.train.batch(&(0..ecfg.model.batch).collect::<Vec<_>>());
        for _ in 0..32 {
            trainer.iteration(Some((xb.clone(), oh.clone()))).unwrap();
        }
        let mut feed: Vec<(Tensor, Tensor)> =
            (0..(warmup + reps)).map(|_| (xb.clone(), oh.clone())).collect();
        feed.reverse();
        let label = format!("dense train_iteration (obs {})", if on { "on" } else { "off" });
        let (s, _) = bench_counted(&label, warmup, reps, || {
            trainer.iteration(Some(feed.pop().expect("prefed batch"))).unwrap()
        });
        print_row(&s);
        s.median_s
    };
    let (mut off_best, mut on_best) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..passes {
        off_best = off_best.min(dense_pass(false));
        on_best = on_best.min(dense_pass(true));
    }
    let dense_overhead_pct = (on_best - off_best) / off_best * 100.0;
    let gate_pct = 2.0;
    let gate_ok = dense_overhead_pct < gate_pct;
    println!(
        "    -> dense obs overhead {dense_overhead_pct:+.2}% (gate < {gate_pct:.0}%: {})",
        if gate_ok { "OK" } else { "FAIL" }
    );

    // Serving: end-to-end round-trip throughput with the span gate off vs
    // on — covers the `serving/forward` span plus the always-on latency
    // histogram / queue gauge / flush counters. Responses stay verified
    // bitwise against the oracle in both modes.
    let mcfg = ModelConfig {
        batch: 32,
        input_dim: 64,
        hidden_dim: 64,
        classes: 10,
        layers: 4,
        init_scale: 1.0,
    };
    let net = Network::build(&NetworkSpec::mlp(&mcfg), &mut Rng::new(31)).unwrap();
    let be = HostBackend::new();
    let mut oracle = net.snapshot().unwrap();
    let per_client = if smoke { 200 } else { 1000 };
    let mut serve_pass = |on: bool| -> f64 {
        obs::set_enabled(on);
        let server = Server::start(
            Arc::new(HostBackend::new()),
            &net,
            &ServerConfig {
                max_batch: 8,
                max_wait_ticks: 2,
                queue_depth: 64,
                stages: 2,
                ..ServerConfig::default()
            },
        )
        .expect("server start");
        let inputs = vec![Tensor::randn(&[4, mcfg.input_dim], 1.0, &mut Rng::new(7))];
        let expected = vec![vec![oracle.forward_full(&be, &inputs[0]).unwrap()]];
        let sw = std::time::Instant::now();
        std::thread::scope(|s| {
            let inputs = &inputs;
            let expected = &expected;
            for _ in 0..2 {
                let mut cl = server.client();
                s.spawn(move || {
                    layerpipe2::serving::drive_and_verify(&mut cl, inputs, expected, |_| 0, per_client, 8)
                        .expect("responses must stay bitwise == oracle with obs toggled");
                });
            }
        });
        let elapsed = sw.elapsed().as_secs_f64();
        server.shutdown().expect("shutdown");
        (2 * per_client) as f64 / elapsed
    };
    let (mut serve_off, mut serve_on) = (0.0f64, 0.0f64);
    for _ in 0..passes {
        serve_off = serve_off.max(serve_pass(false));
        serve_on = serve_on.max(serve_pass(true));
    }
    let serve_overhead_pct = (serve_off - serve_on) / serve_off * 100.0;
    println!(
        "    -> serving {serve_off:.0} req/s (obs off) vs {serve_on:.0} req/s (obs on): \
         {serve_overhead_pct:+.2}% overhead"
    );
    obs::set_enabled(true); // restore the default gate for later sections

    jobj(vec![
        ("dense_ns_obs_off", jnum(off_best * 1e9)),
        ("dense_ns_obs_on", jnum(on_best * 1e9)),
        ("dense_overhead_pct", jnum(dense_overhead_pct)),
        ("gate_pct", jnum(gate_pct)),
        ("gate_ok", Json::Bool(gate_ok)),
        ("serving_rps_obs_off", jnum(serve_off)),
        ("serving_rps_obs_on", jnum(serve_on)),
        ("serving_overhead_pct", jnum(serve_overhead_pct)),
    ])
}

fn main() {
    let smoke = smoke();
    if smoke {
        println!("[smoke mode: reduced sizes and sample counts]");
    }
    let kernels = host_kernel_section(smoke);
    let kernel_family = kernel_family_section(smoke);
    let mixed = mixed_precision_section(smoke);
    let layers = layers_section(smoke);
    let attention = attention_section(smoke);
    pjrt_section();
    let train = train_iteration_section(smoke);
    let executor = executor_pool_section(smoke);
    let serving = serving_section(smoke);
    let adaptive = adaptive_section(smoke);
    let ring = ring_section(smoke);
    let observability = observability_section(smoke);

    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("runtime_hotpath".to_string()));
    obj.insert("smoke".to_string(), Json::Bool(smoke));
    obj.insert("host_kernels".to_string(), kernels);
    obj.insert("train_iteration".to_string(), train);
    obj.insert("executor_pool".to_string(), executor);
    let path = std::env::var("LAYERPIPE2_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&path, Json::Obj(obj).to_string()).expect("write bench json");
    println!("\nwrote {path}");

    // Layer-zoo perf lives in its own trajectory file.
    let mut lobj = BTreeMap::new();
    lobj.insert("bench".to_string(), Json::Str("runtime_hotpath/layers".to_string()));
    lobj.insert("smoke".to_string(), Json::Bool(smoke));
    lobj.insert("conv_kernels".to_string(), layers);
    lobj.insert("attention".to_string(), attention);
    let lpath = std::env::var("LAYERPIPE2_BENCH_LAYERS_JSON")
        .unwrap_or_else(|_| "BENCH_layers.json".to_string());
    std::fs::write(&lpath, Json::Obj(lobj).to_string()).expect("write layers bench json");
    println!("wrote {lpath}");

    // Kernel-family before/after (serial vs packed vs parallel/tree):
    // its own trajectory file so the kernel layer is tracked across PRs.
    let mut kobj = BTreeMap::new();
    kobj.insert("bench".to_string(), Json::Str("runtime_hotpath/kernels".to_string()));
    kobj.insert("smoke".to_string(), Json::Bool(smoke));
    kobj.insert(
        "workers".to_string(),
        Json::Num(layerpipe2::tensor::workers::pool_size() as f64),
    );
    kobj.insert("kernels".to_string(), kernel_family);
    kobj.insert("mixed_precision".to_string(), mixed);
    let kpath = std::env::var("LAYERPIPE2_BENCH_KERNELS_JSON")
        .unwrap_or_else(|_| "BENCH_kernels.json".to_string());
    std::fs::write(&kpath, Json::Obj(kobj).to_string()).expect("write kernels bench json");
    println!("wrote {kpath}");

    // Serving throughput/latency: its own trajectory file so the
    // forward-only serving path is tracked across PRs.
    let mut sobj = BTreeMap::new();
    sobj.insert("bench".to_string(), Json::Str("runtime_hotpath/serving".to_string()));
    sobj.insert("smoke".to_string(), Json::Bool(smoke));
    sobj.insert("serving".to_string(), serving);
    sobj.insert("adaptive".to_string(), adaptive);
    let spath = std::env::var("LAYERPIPE2_BENCH_SERVING_JSON")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    std::fs::write(&spath, Json::Obj(sobj).to_string()).expect("write serving bench json");
    println!("wrote {spath}");

    // Weight-ring replica scaling: its own trajectory file so the 2D
    // (pipeline × data) training path is tracked across PRs.
    let mut robj = BTreeMap::new();
    robj.insert("bench".to_string(), Json::Str("runtime_hotpath/ring".to_string()));
    robj.insert("smoke".to_string(), Json::Bool(smoke));
    robj.insert("ring".to_string(), ring);
    let rpath = std::env::var("LAYERPIPE2_BENCH_RING_JSON")
        .unwrap_or_else(|_| "BENCH_ring.json".to_string());
    std::fs::write(&rpath, Json::Obj(robj).to_string()).expect("write ring bench json");
    println!("wrote {rpath}");

    // Observability overhead + the full instrument inventory the bench
    // run accumulated: its own trajectory file, gated by verify.sh.
    let mut oobj = BTreeMap::new();
    oobj.insert("bench".to_string(), Json::Str("runtime_hotpath/observability".to_string()));
    oobj.insert("smoke".to_string(), Json::Bool(smoke));
    oobj.insert("observability".to_string(), observability);
    oobj.insert("telemetry".to_string(), obs::TelemetrySnapshot::capture().to_json());
    let opath = std::env::var("LAYERPIPE2_BENCH_OBSERVABILITY_JSON")
        .unwrap_or_else(|_| "BENCH_observability.json".to_string());
    std::fs::write(&opath, Json::Obj(oobj).to_string()).expect("write observability bench json");
    println!("wrote {opath}");
}
