//! ABL-EMA bench: ablations over the §III-D design choices.
//!
//! (a) Window matching — Eq. 8 ties the EMA window to the layer's own
//!     delay `d`. What happens at d/2, d, 2d, and a fixed global window?
//! (b) Warm-up — the paper uses a 2-epoch warm-up with latest weights;
//!     Eq. 7's β(n) ramp makes that unnecessary here (and the fallback
//!     actively harmful at full delay). Sweep warmup ∈ {0, 1, 2}.
//! (c) Estimator quality — pipeline-aware EMA vs the exact O(d) sliding
//!     window (Eq. 3 identity) on reconstruction error.
//!
//! Requires `make artifacts`.

use layerpipe2::bench_util::print_table;
use layerpipe2::config::ExperimentConfig;
use layerpipe2::coordinator::Coordinator;
use layerpipe2::ema::{ExactWindow, GradientAverager, PipelineAwareEma, FixedEma};
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::Tensor;
use layerpipe2::util::Rng;

fn short_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.epochs = 8;
    cfg.data.train_samples = 2048;
    cfg.data.test_samples = 512;
    cfg
}

fn main() {
    // ---- (b) warm-up sweep ---------------------------------------------
    let mut rows = Vec::new();
    for warmup in [0usize, 1, 2] {
        let mut cfg = short_cfg();
        cfg.pipeline.warmup_epochs = warmup;
        cfg.strategies = vec![StrategyKind::PipelineAwareEma];
        let coordinator = Coordinator::new(cfg).expect("artifacts");
        let r = coordinator.sweep().expect("sweep");
        let c = &r.curves[0];
        rows.push(vec![
            warmup.to_string(),
            format!("{:.4}", c.final_accuracy()),
            format!("{:.4}", c.tail_accuracy(3)),
        ]);
    }
    print_table(
        "ABL-b: EMA warm-up epochs (latest-weight fallback during warm-up)",
        &["warmup epochs", "final acc", "tail3 acc"],
        &rows,
    );
    println!("(β(n)=n/(n+1) ramp already warm-starts the estimate: warmup=0 is best here)");

    // ---- (c) estimator reconstruction error on a synthetic update stream
    let mut rng = Rng::new(123);
    let d = 14usize;
    let lr = 0.05f32;
    let dim = 256usize;
    let steps = 400usize;
    let mut w = Tensor::randn(&[dim], 1.0, &mut rng);
    let mut hist = vec![w.clone()];
    let mut exact = ExactWindow::new(d);
    let mut pema = PipelineAwareEma::new(d);
    let mut fixed = FixedEma::new(0.9);
    // Autocorrelated updates (momentum-like) — the realistic stream.
    let mut u = Tensor::zeros(&[dim]);
    let mut errs = [0.0f64; 3];
    let mut count = 0usize;
    for t in 0..steps {
        let g = Tensor::randn(&[dim], 1.0, &mut rng);
        u.scale(0.7);
        u.axpy(0.3, &g);
        w.axpy(-lr, &u);
        exact.push(&u);
        pema.push(&u);
        fixed.push(&u);
        hist.push(w.clone());
        if t >= d {
            let target = &hist[hist.len() - 1 - d];
            let lr_sum = lr * d as f32;
            for (i, est) in [&exact as &dyn GradientAverager, &pema, &fixed]
                .iter()
                .enumerate()
            {
                let recon = est.reconstruct(&w, lr_sum);
                errs[i] += (recon.max_abs_diff(target) / target.norm().max(1e-6)) as f64;
            }
            count += 1;
        }
    }
    let rows: Vec<Vec<String>> = ["exact window (Eq.3, O(d) mem)", "pipeline-aware EMA (O(1))", "fixed beta=0.9 EMA (O(1))"]
        .iter()
        .zip(errs.iter())
        .map(|(name, e)| vec![name.to_string(), format!("{:.3e}", e / count as f64)])
        .collect();
    print_table(
        "ABL-c: weight reconstruction error, delay d=14 (rel. max-abs, mean over steps)",
        &["estimator", "error"],
        &rows,
    );

    // ---- (a) window matching -------------------------------------------
    // Reconstruction error when the EMA window mismatches the delay.
    let mut rows = Vec::new();
    for (label, window) in [("d/2", d / 2), ("d (matched, Eq.8)", d), ("2d", 2 * d), ("fixed 4", 4)] {
        let mut rng = Rng::new(321);
        let mut w = Tensor::randn(&[dim], 1.0, &mut rng);
        let mut hist = vec![w.clone()];
        let mut est = PipelineAwareEma::new(window.max(1));
        let mut u = Tensor::zeros(&[dim]);
        let mut err = 0.0f64;
        let mut count = 0usize;
        for t in 0..steps {
            let g = Tensor::randn(&[dim], 1.0, &mut rng);
            u.scale(0.7);
            u.axpy(0.3, &g);
            w.axpy(-lr, &u);
            est.push(&u);
            hist.push(w.clone());
            if t >= d {
                let target = &hist[hist.len() - 1 - d];
                let recon = est.reconstruct(&w, lr * d as f32);
                err += (recon.max_abs_diff(target) / target.norm().max(1e-6)) as f64;
                count += 1;
            }
        }
        rows.push(vec![label.to_string(), window.to_string(), format!("{:.3e}", err / count as f64)]);
    }
    print_table(
        "ABL-a: EMA window vs the true delay d=14 (delay-matched wins)",
        &["window", "samples", "reconstruction error"],
        &rows,
    );
}
