//! THRU bench: pipeline throughput & utilization (LayerPipe's headline,
//! reaffirmed in §IV-D) — both the analytic schedule model and the real
//! threaded runtime over whichever backend is available (PJRT artifacts
//! when present, the pure-Rust host backend otherwise).
//!
//! Paper shape to hold: speedup grows with stage count, bounded by the
//! bottleneck stage; utilization stays high for balanced partitions;
//! communication volume grows with boundaries.
//!
//! The threaded runtime section exercises the zero-allocation hot path:
//! stage workers run `forward_into` on stage-local `BufferPool`s and the
//! parallel matmuls dispatch to the persistent `WorkerPool` (no per-call
//! thread spawns), so measured batches/sec reflect steady-state kernel
//! cost rather than allocator/spawn churn.

use layerpipe2::backend::{self, Exec};
use layerpipe2::bench_util::print_table;
use layerpipe2::model::Mlp;
use layerpipe2::pipeline::{forward_sequential, forward_throughput};
use layerpipe2::retiming::StagePartition;
use layerpipe2::runtime::Manifest;
use layerpipe2::schedule::{evaluate, CostModel};
use layerpipe2::tensor::Tensor;
use layerpipe2::util::Rng;

fn main() {
    // --- analytic model: speedup/utilization/comm vs stages -------------
    let layers = 8;
    let mut cost = CostModel::uniform(layers);
    cost.boundary_bytes = 32 * 64 * 4; // batch x hidden f32 activations
    let mut rows = Vec::new();
    for stages in [1usize, 2, 4, 8] {
        let p = StagePartition::even(layers, stages).unwrap();
        let perf = evaluate(&p, &cost, 10_000);
        rows.push(vec![
            stages.to_string(),
            format!("{:.2}x", perf.speedup),
            format!("{:.3}", perf.mean_utilization),
            format!("{:.1}", perf.comm_bytes as f64 / 1e6),
            format!("{:.1}", perf.bottleneck_cost),
        ]);
    }
    print_table(
        "THRU-a: analytic schedule model (8 uniform layers, 10k batches)",
        &["stages", "speedup", "utilization", "comm MB", "bottleneck"],
        &rows,
    );

    // --- unbalanced partitions: bottleneck caps speedup ----------------
    let mut skew = CostModel::uniform(8);
    skew.fwd[4] = 4.0;
    skew.bwd[4] = 8.0;
    let mut rows = Vec::new();
    for stages in [2usize, 4, 8] {
        let p = StagePartition::even(8, stages).unwrap();
        let perf = evaluate(&p, &skew, 10_000);
        rows.push(vec![
            stages.to_string(),
            format!("{:.2}x", perf.speedup),
            format!("{:.3}", perf.mean_utilization),
        ]);
    }
    print_table(
        "THRU-b: skewed layer 4 at 4x cost (bottleneck-capped speedup)",
        &["stages", "speedup", "utilization"],
        &rows,
    );

    // --- multiprocessor assignment: LPT vs contiguous -------------------
    // (the LayerPipe multiprocessor-scheduling axis: balance vs locality)
    use layerpipe2::schedule::{assign_contiguous, assign_lpt, simulate_multiproc};
    let mut skew2 = CostModel::uniform(8);
    skew2.fwd[1] = 3.0;
    skew2.bwd[1] = 6.0;
    skew2.fwd[6] = 2.0;
    skew2.bwd[6] = 4.0;
    let p8 = StagePartition::even(8, 8).unwrap();
    let mut rows = Vec::new();
    for procs in [2usize, 4, 8] {
        let lpt = simulate_multiproc(&p8, &skew2, &assign_lpt(&p8, &skew2, procs), 10_000);
        let con = simulate_multiproc(&p8, &skew2, &assign_contiguous(&p8, procs), 10_000);
        rows.push(vec![
            procs.to_string(),
            format!("{:.2}x / {}", lpt.speedup, lpt.remote_boundaries),
            format!("{:.2}x / {}", con.speedup, con.remote_boundaries),
        ]);
    }
    print_table(
        "THRU-d: processor assignment on skewed layers (speedup / remote boundaries)",
        &["procs", "LPT (balance)", "contiguous (locality)"],
        &rows,
    );

    // --- real threaded pipeline over the selected backend ---------------
    let backend = backend::from_env("artifacts").expect("backend selection");
    let cfg = Manifest::model_config_or_default("artifacts");
    let mut rng = Rng::new(3);
    let mlp = Mlp::init(&cfg, &mut rng);
    let inputs: Vec<Tensor> =
        (0..8).map(|_| Tensor::randn(&[cfg.batch, cfg.input_dim], 1.0, &mut rng)).collect();
    let batches = 300;
    let seq = forward_sequential(&backend, &mlp, &inputs, batches).unwrap();
    let mut rows = vec![vec![
        "sequential(1 thread)".to_string(),
        format!("{:.0}", seq.batches_per_sec),
        "1.00x".to_string(),
    ]];
    for stages in [2usize, 4, 8] {
        let p = StagePartition::even(cfg.layers, stages).unwrap();
        let r = forward_throughput(&backend, &mlp, &p, inputs.clone(), batches, 4).unwrap();
        rows.push(vec![
            format!("pipeline({stages} stages)"),
            format!("{:.0}", r.batches_per_sec),
            format!("{:.2}x", r.batches_per_sec / seq.batches_per_sec),
        ]);
    }
    print_table(
        &format!("THRU-c: threaded pipeline on real compute (300 batches, backend: {})", backend.name()),
        &["configuration", "batches/s", "speedup"],
        &rows,
    );
}
