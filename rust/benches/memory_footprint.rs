//! MEM bench: the O(L·S) → O(L) weight-state reduction (paper §III-D).
//!
//! Sweeps depth and stage count, accounting exact bytes held by the
//! stashing baseline (one weight version per in-flight iteration per
//! layer) versus the pipeline-aware EMA (one accumulator per layer).
//! The paper's claim: stash memory grows with L·S; EMA stays O(L).

use layerpipe2::bench_util::print_table;
use layerpipe2::retiming::StagePartition;
use layerpipe2::stash::WeightStash;
use layerpipe2::ema::{GradientAverager, PipelineAwareEma};
use layerpipe2::tensor::Tensor;

/// Bytes of stash state a layer with delay `d` holds for weights of
/// `n` floats (d+1 retained versions), vs the EMA accumulator.
fn account(layers: usize, stages: usize, hidden: usize) -> (usize, usize) {
    let p = StagePartition::even(layers, stages).unwrap();
    let w = Tensor::zeros(&[hidden, hidden]);
    let mut stash_total = 0usize;
    let mut ema_total = 0usize;
    for l in 0..layers {
        let d = p.gradient_delays()[l];
        if d > 0 {
            let mut stash = WeightStash::new(d + 1);
            for t in 0..=(d as u64) {
                stash.push(t, &w);
            }
            stash_total += stash.nbytes();
        }
        let mut ema = PipelineAwareEma::new(d.max(1));
        ema.push(&w);
        ema_total += ema.state_nbytes();
    }
    (stash_total, ema_total)
}

fn main() {
    let hidden = 64;
    let mut rows = Vec::new();
    for layers in [8usize, 16, 32, 64] {
        for stages in [2usize, 4, 8, 16] {
            if stages > layers {
                continue;
            }
            let (stash, ema) = account(layers, stages, hidden);
            rows.push(vec![
                layers.to_string(),
                stages.to_string(),
                format!("{:.1}", stash as f64 / 1024.0),
                format!("{:.1}", ema as f64 / 1024.0),
                format!("{:.1}x", stash as f64 / ema as f64),
            ]);
        }
    }
    print_table(
        "MEM: weight-state bytes — stashing O(L*S) vs pipeline-aware EMA O(L)  (64x64 f32 layers)",
        &["layers L", "stages S", "stash KiB", "EMA KiB", "reduction"],
        &rows,
    );

    // The scaling law itself: with L fixed, stash grows ~linearly in S
    // while EMA is constant.
    let (s2, e2) = account(16, 2, hidden);
    let (s16, e16) = account(16, 16, hidden);
    println!("\nscaling at L=16: stages 2→16 stash {:.1}x (≈S), ema {:.2}x (≈1)",
        s16 as f64 / s2 as f64, e16 as f64 / e2 as f64);
    assert!(s16 as f64 / s2 as f64 > 4.0, "stash must scale with S");
    assert!((e16 as f64 / e2 as f64 - 1.0).abs() < 0.01, "ema must be S-independent");
    println!("scaling law: CONFIRMED");
}
