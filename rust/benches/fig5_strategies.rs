//! FIG5 bench: the headline experiment — test accuracy under pipelined
//! training for the five weight-handling strategies (paper Fig. 5).
//!
//! Short-horizon version of examples/fig5_strategies.rs sized for a
//! bench run; asserts the paper's qualitative shape (who wins / who
//! degrades / memory reduction) and reports per-strategy wall-clock.
//! Requires `make artifacts`.

use layerpipe2::bench_util::print_table;
use layerpipe2::config::ExperimentConfig;
use layerpipe2::coordinator::{check_fig5_shape, Coordinator};

fn main() {
    let mut cfg = ExperimentConfig::default();
    // Short-horizon bench sizing: stashing's delayed-but-consistent
    // gradients converge ~2x slower per epoch, so give the sweep enough
    // epochs for the steady-state ordering to emerge (the full-length
    // run lives in examples/fig5_strategies.rs / EXPERIMENTS.md).
    cfg.epochs = 16;
    cfg.data.train_samples = 2048;
    cfg.data.test_samples = 512;

    let coordinator = Coordinator::new(cfg).expect("artifacts present");
    let result = coordinator.sweep().expect("sweep");

    let mut rows = Vec::new();
    for c in &result.curves {
        let secs: f64 = c.epochs.iter().map(|e| e.seconds).sum();
        rows.push(vec![
            c.strategy.clone(),
            format!("{:.4}", c.final_accuracy()),
            format!("{:.4}", c.best_accuracy()),
            format!("{:.4}", c.tail_accuracy(3)),
            format!("{}", c.peak_staleness_bytes()),
            format!("{secs:.2}s"),
        ]);
    }
    print_table(
        "FIG5: weight-handling strategies (10 epochs, 8-stage pipeline)",
        &["strategy", "final acc", "best acc", "tail3 acc", "staleness bytes", "time"],
        &rows,
    );

    let problems = check_fig5_shape(&result);
    if problems.is_empty() {
        println!("\nshape check: REPRODUCED (stashing≈sequential, latest degrades,");
        println!("pipeline-aware EMA recovers at O(L) memory)");
    } else {
        println!("\nshape check deviations:");
        for p in &problems {
            println!("  - {p}");
        }
        std::process::exit(1);
    }
}
