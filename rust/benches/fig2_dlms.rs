//! FIG2 bench: delayed-LMS adaptation (paper Fig. 2 / §III-A).
//!
//! Regenerates the figure's series: convergence behaviour vs update
//! delay M, plus the delay-tightened stability boundary, plus raw
//! simulator throughput. Paper shape to hold: convergence survives
//! moderate delay, slows as M grows, and diverges past the μ bound.

use layerpipe2::bench_util::{bench, print_header, print_row, print_table};
use layerpipe2::dlms::{convergence_time, run, stable_mu_bound, DlmsConfig};

fn main() {
    // --- series 1: convergence vs delay --------------------------------
    let mut rows = Vec::new();
    for delay in [0usize, 1, 2, 4, 8, 16, 32, 64] {
        let cfg = DlmsConfig { delay, mu: 0.01, ..Default::default() };
        let r = run(&cfg);
        rows.push(vec![
            delay.to_string(),
            format!("{:.3e}", r.misalignment),
            format!("{:.3e}", r.steady_state_mse),
            convergence_time(&r.mse_curve, 1e-3)
                .map(|t| t.to_string())
                .unwrap_or_else(|| "never".into()),
            r.converged.to_string(),
        ]);
    }
    print_table(
        "FIG2a: DLMS convergence vs delay (16-tap FIR, mu=0.01)",
        &["delay M", "misalignment", "steady MSE", "conv@1e-3", "stable"],
        &rows,
    );

    // --- series 2: stability boundary vs delay -------------------------
    let mut rows = Vec::new();
    for delay in [0usize, 4, 16, 64] {
        let bound = stable_mu_bound(16, delay, 1.0);
        let at_half = run(&DlmsConfig { delay, mu: 0.5 * bound, samples: 30_000, ..Default::default() });
        let at_2x = run(&DlmsConfig { delay, mu: 2.0 * bound, samples: 30_000, ..Default::default() });
        rows.push(vec![
            delay.to_string(),
            format!("{bound:.4}"),
            (at_half.converged && at_half.steady_state_mse < 1e-2).to_string(),
            (!(at_2x.converged && at_2x.steady_state_mse < 1e-2)).to_string(),
        ]);
    }
    print_table(
        "FIG2b: stability boundary (stable at mu/2, diverges at 2mu)",
        &["delay M", "mu bound", "stable@0.5x", "unstable@2x"],
        &rows,
    );

    // --- timing ---------------------------------------------------------
    print_header("FIG2 timing: simulator throughput");
    for delay in [0usize, 16, 64] {
        let cfg = DlmsConfig { delay, samples: 20_000, ..Default::default() };
        let s = bench(&format!("dlms_20k_samples/delay={delay}"), 1, 10, || run(&cfg));
        print_row(&s);
    }
}
