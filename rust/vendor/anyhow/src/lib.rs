//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this path dependency
//! implements exactly the subset of `anyhow` the workspace uses:
//!
//! - [`Error`]: a context-chain error type. `{}` displays the outermost
//!   context, `{:#}` joins the whole chain with `": "` (matching anyhow's
//!   alternate formatting), `{:?}` renders a `Caused by:` report.
//! - [`Result<T>`] with the error type defaulted.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! - `From<E: std::error::Error>` so `?` lifts std errors (io, parse, fmt)
//!   into [`Error`], preserving their source chains.
//!
//! Swapping in the real crate is a one-line Cargo.toml change; no call
//! site depends on anything beyond this subset.

use std::fmt;

/// Context-chain error. `chain[0]` is the outermost (most recently added)
/// context; the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` produces).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend one more layer of context.
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps the blanket `From` below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment for `Result` and `Option` (`anyhow::Context`).
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")`: build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")`: early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")`: `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outermost_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "missing x");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            let n: u32 = "42".parse()?; // FromStr error lifts via From
            if n == 0 {
                bail!("zero");
            }
            Ok(n)
        }
        assert_eq!(inner(true).unwrap(), 42);
        let e = inner(false).unwrap_err();
        assert_eq!(format!("{e:#}"), "flag was false");
        let direct = anyhow!("code {}", 7);
        assert_eq!(format!("{direct}"), "code 7");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn f(x: u32) -> Result<()> {
            ensure!(x > 3);
            Ok(())
        }
        let e = f(1).unwrap_err();
        assert!(format!("{e}").contains("x > 3"));
    }
}
