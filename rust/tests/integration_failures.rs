//! Failure injection: the runtime must fail loudly and legibly, never
//! crash in XLA or silently compute garbage.
//!
//! Manifest-parsing failures are exercised unconditionally; the
//! engine-load failures need the `pjrt` feature (without it `Engine` is
//! a stub whose only failure mode is "feature missing", covered by its
//! unit test).

use layerpipe2::runtime::Manifest;

#[cfg(feature = "pjrt")]
use layerpipe2::runtime::Engine;
#[cfg(feature = "pjrt")]
use std::io::Write;

#[cfg(feature = "pjrt")]
fn write_dir(files: &[(&str, &str)]) -> tempdir::TempDirLite {
    let dir = tempdir::TempDirLite::new("lp2_fail");
    for (name, content) in files {
        let mut f = std::fs::File::create(dir.path().join(name)).unwrap();
        f.write_all(content.as_bytes()).unwrap();
    }
    dir
}

/// Minimal tempdir (the tempfile crate is unavailable offline).
#[cfg(feature = "pjrt")]
mod tempdir {
    use std::path::{Path, PathBuf};
    use std::sync::atomic::{AtomicU64, Ordering};

    static COUNTER: AtomicU64 = AtomicU64::new(0);

    pub struct TempDirLite(PathBuf);

    impl TempDirLite {
        pub fn new(prefix: &str) -> Self {
            let n = COUNTER.fetch_add(1, Ordering::Relaxed);
            let p = std::env::temp_dir().join(format!(
                "{prefix}_{}_{n}",
                std::process::id()
            ));
            std::fs::create_dir_all(&p).unwrap();
            TempDirLite(p)
        }

        pub fn path(&self) -> &Path {
            &self.0
        }
    }

    impl Drop for TempDirLite {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }
}

const MINI_MANIFEST: &str = r#"{
  "preset": "tiny", "fingerprint": "x",
  "model": {"batch": 2, "input_dim": 2, "hidden_dim": 2, "classes": 2, "layers": 2},
  "entries": [
    {"name": "only", "file": "only.hlo.txt",
     "inputs": [[2, 2]], "outputs": 1, "output_shapes": [[2, 2]]}
  ]
}"#;

#[cfg(feature = "pjrt")]
#[test]
fn missing_manifest_dir_is_a_clear_error() {
    let err = Engine::load("/nonexistent/path").err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "got: {msg}");
}

#[cfg(feature = "pjrt")]
#[test]
fn corrupt_manifest_json_is_rejected() {
    let dir = write_dir(&[("manifest.json", "{not json")]);
    let err = Engine::load(dir.path().to_str().unwrap()).err().expect("must fail");
    assert!(format!("{err:#}").contains("JSON"), "{err:#}");
}

#[cfg(feature = "pjrt")]
#[test]
fn manifest_referencing_missing_hlo_file_is_rejected() {
    let dir = write_dir(&[("manifest.json", MINI_MANIFEST)]);
    let err = Engine::load(dir.path().to_str().unwrap()).err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("only"), "names the bad entry: {msg}");
}

#[cfg(feature = "pjrt")]
#[test]
fn garbage_hlo_text_is_rejected_at_compile_time() {
    let dir = write_dir(&[
        ("manifest.json", MINI_MANIFEST),
        ("only.hlo.txt", "this is not HLO at all"),
    ]);
    let err = Engine::load(dir.path().to_str().unwrap()).err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("only") || msg.contains("HLO") || msg.contains("pars"),
        "load-time rejection, got: {msg}"
    );
}

#[test]
fn manifest_parse_rejects_wrong_types() {
    let bad = MINI_MANIFEST.replace("\"batch\": 2", "\"batch\": \"two\"");
    assert!(Manifest::parse(&bad).is_err());
    let bad = MINI_MANIFEST.replace("[[2, 2]]", "[[2, -2]]");
    assert!(Manifest::parse(&bad).is_err());
}

#[test]
fn manifest_parse_accepts_the_mini_manifest() {
    let m = Manifest::parse(MINI_MANIFEST).unwrap();
    assert_eq!(m.preset, "tiny");
    assert_eq!(m.entries.len(), 1);
    assert_eq!(m.model.batch, 2);
}
