//! Integration: the transformer layer zoo end to end.
//!
//! The acceptance bar of the transformer PR:
//!  - an Embedding → [SelfAttention → LayerNorm → Dense] × blocks stack
//!    trains through the multi-threaded `PipelinedTrainer` with stage
//!    boundaries from `StagePartition::balanced` over the new layers'
//!    cost reports, matching the iteration-indexed `Trainer` oracle
//!    ≤ 1e-4 for **all five** weight-version strategies;
//!  - gradient delays stay `2·S(l)` (downstream stage count only) —
//!    the paper's Eq. 1 rule generalizes unchanged to attention stacks;
//!  - training is bit-identical across `LAYERPIPE2_WORKERS` 1..=8
//!    (the masked softmax, embedding scatter and layernorm reductions
//!    hold the kernel family's determinism contract);
//!  - transformer checkpoints roundtrip;
//!  - the stack actually learns the token-teacher task.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::{token_teacher_dataset, Splits};
use layerpipe2::layers::{Feature, LayerSpec, Network, NetworkSpec};
use layerpipe2::metrics::RunCurve;
use layerpipe2::model::checkpoint;
use layerpipe2::pipeline::PipelinedTrainer;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::Tensor;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn host() -> Backend {
    Arc::new(HostBackend::new())
}

const SEQ: usize = 6;
const DM: usize = 6;
const VOCAB: usize = 12;
const CLASSES: usize = 4;

/// One causal block plus classifier head — every new layer kind in one
/// stack, 3 cost-balanced stages.
fn transformer_spec() -> NetworkSpec {
    NetworkSpec {
        input: Feature::Flat(SEQ),
        layers: vec![
            LayerSpec::Embedding { vocab: VOCAB, dim: DM },
            LayerSpec::SelfAttention { seq: SEQ, d_model: DM, causal: true },
            LayerSpec::LayerNorm { eps: 1e-5 },
            LayerSpec::Dense { units: SEQ * DM, relu: true },
            LayerSpec::SelfAttention { seq: SEQ, d_model: DM, causal: true },
            LayerSpec::LayerNorm { eps: 1e-5 },
            LayerSpec::Dense { units: CLASSES, relu: false },
        ],
        init_scale: 1.0,
    }
}

fn transformer_cfg(epochs: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 8;
    cfg.model.input_dim = SEQ;
    cfg.model.hidden_dim = SEQ * DM;
    cfg.model.classes = CLASSES;
    cfg.model.layers = 7;
    cfg.pipeline.stages = 3;
    cfg.epochs = epochs;
    cfg.seed = 17;
    cfg.data = DataConfig {
        train_samples: 96,
        test_samples: 48,
        teacher_hidden: 12,
        label_noise: 0.0,
        seed: 23,
    };
    cfg
}

fn transformer_data(cfg: &ExperimentConfig) -> Splits {
    token_teacher_dataset(SEQ, VOCAB, CLASSES, &cfg.data)
}

/// Train the same (config, spec, strategy) on both engines with the
/// coordinator's seed discipline.
fn run_both(
    cfg: &ExperimentConfig,
    spec: &NetworkSpec,
    data: &Splits,
    kind: StrategyKind,
) -> (RunCurve, RunCurve) {
    let oracle = {
        let mut rng = Rng::new(cfg.seed);
        let mut t = Trainer::with_spec(host(), cfg, spec, kind, &mut rng).expect("oracle init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        t.train(data, &mut batch_rng).expect("oracle train")
    };
    let threaded = {
        let mut rng = Rng::new(cfg.seed);
        let mut ex =
            PipelinedTrainer::with_spec(host(), cfg, spec, kind, &mut rng).expect("executor init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        ex.train(data, &mut batch_rng).expect("executor train")
    };
    (oracle, threaded)
}

fn assert_curves_match(kind: StrategyKind, oracle: &RunCurve, threaded: &RunCurve, tol: f32) {
    assert_eq!(oracle.epochs.len(), threaded.epochs.len(), "{kind:?}: epoch count");
    for (e, (a, b)) in oracle.epochs.iter().zip(&threaded.epochs).enumerate() {
        assert!(
            a.train_loss.is_finite() && b.train_loss.is_finite(),
            "{kind:?} epoch {e}: non-finite loss ({} vs {})",
            a.train_loss,
            b.train_loss
        );
        assert!(
            (a.train_loss - b.train_loss).abs() <= tol,
            "{kind:?} epoch {e}: oracle loss {} vs executor {}",
            a.train_loss,
            b.train_loss
        );
        assert!(
            (a.test_accuracy - b.test_accuracy).abs() <= tol,
            "{kind:?} epoch {e}: oracle acc {} vs executor {}",
            a.test_accuracy,
            b.test_accuracy
        );
        assert_eq!(
            a.staleness_bytes, b.staleness_bytes,
            "{kind:?} epoch {e}: staleness accounting diverged"
        );
    }
}

#[test]
fn transformer_executor_matches_oracle_for_all_five_strategies() {
    // The PR's acceptance bar: embedding + attention + layernorm through
    // real threaded stages, every Fig. 5 strategy within 1e-4.
    let cfg = transformer_cfg(3);
    let spec = transformer_spec();
    let data = transformer_data(&cfg);
    for &kind in StrategyKind::all() {
        let (oracle, threaded) = run_both(&cfg, &spec, &data, kind);
        assert_curves_match(kind, &oracle, &threaded, 1e-4);
    }
}

#[test]
fn transformer_partition_is_cost_balanced_with_eq1_delays() {
    let cfg = transformer_cfg(1);
    let spec = transformer_spec();
    let mut rng = Rng::new(cfg.seed);
    let t = Trainer::with_spec(host(), &cfg, &spec, StrategyKind::Stashing, &mut rng).unwrap();
    let p = t.partition();
    assert_eq!(p.stages(), 3);
    // Boundaries must be the balanced optimum over the new layers' cost
    // reports — attention dominates, embedding/layernorm are cheap.
    let net = Network::build(&spec, &mut Rng::new(0)).unwrap();
    let costs: Vec<u64> = net.costs(cfg.model.batch).iter().map(|c| c.total_flops()).collect();
    let best = layerpipe2::retiming::StagePartition::balanced(&costs, 3).unwrap();
    assert_eq!(p.stage_of(), best.stage_of());
    assert_eq!(p.max_stage_cost(&costs), best.max_stage_cost(&costs));
    // Delays depend only on downstream stage count (paper Eq. 1).
    let delays = t.gradient_delays();
    for (l, &d) in delays.iter().enumerate() {
        assert_eq!(d, 2 * p.downstream_stages(l));
    }
}

#[test]
fn transformer_training_is_bit_identical_across_runs() {
    // Two identical end-to-end runs through the threaded executor must
    // produce bit-identical parameters. The worker pool is process-
    // global (its size is fixed at first spawn), so the 1..=8
    // worker-count sweep lives at the kernel-composition level — the
    // attention unit tests compare layer outputs against explicit
    // `_with_threads` compositions for every count, and embedding /
    // layernorm are serial by construction. What this test adds on top:
    // the full trainer (pool-parallel matmuls, masked softmax, scatter,
    // reductions, stage threads) has no run-to-run nondeterminism.
    let cfg = transformer_cfg(1);
    let spec = transformer_spec();
    let data = transformer_data(&cfg);
    let run = || -> Vec<Tensor> {
        let mut rng = Rng::new(cfg.seed);
        let mut ex =
            PipelinedTrainer::with_spec(host(), &cfg, &spec, StrategyKind::PipelineAwareEma, &mut rng)
                .unwrap();
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        ex.train(&data, &mut batch_rng).unwrap();
        let net = ex.network().unwrap();
        let mut params = Vec::new();
        for nl in &net.layers {
            params.push(nl.w.clone());
            params.push(nl.b.clone());
        }
        params
    };
    let base = run();
    let again = run();
    for (i, (a, b)) in base.iter().zip(&again).enumerate() {
        assert_eq!(a, b, "param tensor {i} drifted between identical runs");
    }
}

#[test]
fn transformer_learns_on_token_teacher_data() {
    let mut cfg = transformer_cfg(6);
    cfg.data.train_samples = 256;
    cfg.data.test_samples = 96;
    let spec = transformer_spec();
    let data = transformer_data(&cfg);
    let mut rng = Rng::new(cfg.seed);
    let mut t =
        Trainer::with_spec(host(), &cfg, &spec, StrategyKind::Sequential, &mut rng).unwrap();
    let mut batch_rng = Rng::new(5);
    let curve = t.train(&data, &mut batch_rng).unwrap();
    let chance = 1.0 / CLASSES as f32;
    assert!(
        curve.final_accuracy() > 1.25 * chance,
        "transformer failed to learn: {} (chance {chance})",
        curve.final_accuracy()
    );
    let first = curve.epochs.first().unwrap().train_loss;
    let last = curve.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss {first} → {last}");
}

#[test]
fn transformer_checkpoint_roundtrips_through_training() {
    let cfg = transformer_cfg(1);
    let spec = transformer_spec();
    let data = transformer_data(&cfg);
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::with_spec(host(), &cfg, &spec, StrategyKind::Latest, &mut rng).unwrap();
    let mut batch_rng = Rng::new(5);
    t.train(&data, &mut batch_rng).unwrap();

    let bytes = checkpoint::network_to_bytes(&t.net);
    let mut restored = Network::build(&spec, &mut Rng::new(999)).unwrap();
    checkpoint::network_from_bytes(&mut restored, &bytes).unwrap();
    for (a, b) in t.net.layers.iter().zip(&restored.layers) {
        assert_eq!(a.w, b.w);
        assert_eq!(a.b, b.b);
    }
    // Token inputs must evaluate identically through the restored net.
    let mut ids = Tensor::zeros(&[4, SEQ]);
    let mut rng = Rng::new(3);
    for v in ids.data_mut().iter_mut() {
        *v = rng.index(VOCAB) as f32;
    }
    let be = HostBackend::new();
    let mut snap = t.net.snapshot().unwrap();
    assert_eq!(
        snap.forward_full(&be, &ids).unwrap(),
        restored.forward_full(&be, &ids).unwrap()
    );
}

#[test]
fn transformer_executor_snapshot_matches_oracle_params_bitwise() {
    // After identical training, the stage-distributed parameters must
    // equal the oracle's exactly (the executor is the oracle, threaded).
    let cfg = transformer_cfg(2);
    let spec = transformer_spec();
    let data = transformer_data(&cfg);
    let kind = StrategyKind::Stashing;
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::with_spec(host(), &cfg, &spec, kind, &mut rng).unwrap();
    let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
    t.train(&data, &mut batch_rng).unwrap();
    let mut rng = Rng::new(cfg.seed);
    let mut ex = PipelinedTrainer::with_spec(host(), &cfg, &spec, kind, &mut rng).unwrap();
    let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
    ex.train(&data, &mut batch_rng).unwrap();
    let net = ex.network().unwrap();
    for (l, (a, b)) in t.net.layers.iter().zip(&net.layers).enumerate() {
        assert_eq!(a.w, b.w, "layer {l} weights diverged");
        assert_eq!(a.b, b.b, "layer {l} biases diverged");
    }
}
