//! Counting-allocator proof of the hot-path memory discipline.
//!
//! The buffer-pooled trainer must reach a steady state where one full
//! pipelined iteration (forward + all due delayed backwards + optimizer
//! steps + EMA/stash bookkeeping) performs (near-)zero heap allocation:
//! activations and gradients recycle through the `BufferPool`, `dw`/`db`
//! land in persistent per-layer workspaces, EMA reconstruction reuses
//! its scratch tensor, and weight stashing copies into evicted ring
//! slots. The only tolerated allocations are rare amortized ones
//! (lr-prefix growth, loss-vec doubling) — bounded well under one per
//! iteration on average.
//!
//! This file deliberately holds a single `#[test]` so the counting
//! global allocator sees no concurrent test threads.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::ExperimentConfig;
use layerpipe2::data::{image_teacher_dataset, teacher_dataset};
use layerpipe2::layers::{Feature, LayerSpec, NetworkSpec};
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::{workers, Tensor};
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_iterations_allocate_near_zero() {
    // The default config: 8 layers / 8 stages, max delay 14 — every
    // staleness mechanism (stash ring, EMA recompute, delayed chains)
    // is exercised at full depth.
    let mut cfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
    cfg.data.train_samples = 256;
    cfg.data.test_samples = 64;
    let data = teacher_dataset(&cfg.model, &cfg.data);

    for kind in [
        StrategyKind::Latest,
        StrategyKind::PipelineAwareEma,
        StrategyKind::FixedEma,
        StrategyKind::Stashing,
    ] {
        let backend: Backend = Arc::new(HostBackend::new());
        let mut rng = Rng::new(1);
        let mut trainer = Trainer::new(backend, &cfg, kind, &mut rng).unwrap();
        let (xb, oh) = data.train.batch(&(0..cfg.model.batch).collect::<Vec<_>>());

        // Prime well past the deepest delay (14): fills the pipeline,
        // the buffer pools, the stash rings and the lr prefix cache.
        let prime = 48usize;
        let measure = 32usize;
        // Batches are cloned up front — feeding data is the loader's
        // cost, not the iteration's.
        let mut feed: Vec<(Tensor, Tensor)> =
            (0..(prime + measure)).map(|_| (xb.clone(), oh.clone())).collect();
        feed.reverse();
        for _ in 0..prime {
            trainer.iteration(Some(feed.pop().expect("primed batch"))).unwrap();
        }

        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..measure {
            trainer.iteration(Some(feed.pop().expect("measured batch"))).unwrap();
        }
        let total = ALLOCS.load(Ordering::Relaxed) - before;
        let per_iter = total as f64 / measure as f64;
        println!("{}: {total} allocs over {measure} iters = {per_iter:.2}/iter", kind.name());
        assert!(
            per_iter <= 4.0,
            "steady-state hot path regressed to {per_iter:.2} allocs/iter for {} \
             (expected (near-)zero: pooled activations/gradients, persistent \
             workspaces, in-place EMA and stash reuse)",
            kind.name()
        );
    }

    // ---- bf16 storage path ---------------------------------------------
    //
    // Mixed precision must not regress the discipline: bf16 activations
    // recycle through the (dtype, nbytes)-keyed pool, the forward
    // quantization rides the persistent `fwd_scratch`, the f32 masters
    // step in place and re-quantize into the existing weight storage,
    // and the EMA/stash history stores bf16 in the same recycled slots.
    {
        use layerpipe2::tensor::Dtype;
        let mut bcfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
        bcfg.dtype = Dtype::Bf16;
        bcfg.data.train_samples = 256;
        bcfg.data.test_samples = 64;
        let bdata = teacher_dataset(&bcfg.model, &bcfg.data);
        for kind in [StrategyKind::Stashing, StrategyKind::PipelineAwareEma] {
            let backend: Backend = Arc::new(HostBackend::new());
            let mut rng = Rng::new(1);
            let mut trainer = Trainer::new(backend, &bcfg, kind, &mut rng).unwrap();
            let (xb, oh) = bdata.train.batch(&(0..bcfg.model.batch).collect::<Vec<_>>());
            let prime = 48usize;
            let measure = 32usize;
            let mut feed: Vec<(Tensor, Tensor)> =
                (0..(prime + measure)).map(|_| (xb.clone(), oh.clone())).collect();
            feed.reverse();
            for _ in 0..prime {
                trainer.iteration(Some(feed.pop().expect("primed batch"))).unwrap();
            }
            let before = ALLOCS.load(Ordering::Relaxed);
            for _ in 0..measure {
                trainer.iteration(Some(feed.pop().expect("measured batch"))).unwrap();
            }
            let total = ALLOCS.load(Ordering::Relaxed) - before;
            let per_iter = total as f64 / measure as f64;
            println!(
                "bf16 / {}: {total} allocs over {measure} iters = {per_iter:.2}/iter",
                kind.name()
            );
            assert!(
                per_iter <= 4.0,
                "bf16 hot path regressed to {per_iter:.2} allocs/iter for {} \
                 (expected (near-)zero: dtype-keyed pooled activations, persistent \
                 quantization scratch, in-place master step + re-quantize)",
                kind.name()
            );
        }
    }

    // ---- heterogeneous (conv + pool + dense + LIF) path ----------------
    //
    // The same discipline must hold for the layer zoo: im2col/dcols live
    // in persistent op workspaces, the fused conv epilogue writes the
    // shared scratch, pool/LIF backwards resize zero-length param grads
    // in place. Shapes stay under the parallel-matmul threshold so the
    // worker pool (whose task boxing allocates) never engages — conv
    // parallelism is exercised by the throughput benches instead.
    let (h, w, c, classes) = (8usize, 8usize, 1usize, 4usize);
    let spec = NetworkSpec {
        input: Feature::Image { h, w, c },
        layers: vec![
            LayerSpec::Conv2d { out_c: 4, k: 3, stride: 1, pad: 1, relu: true },
            LayerSpec::MaxPool2d { k: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 32, relu: false },
            LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            LayerSpec::Dense { units: classes, relu: false },
        ],
        init_scale: 1.0,
    };
    let mut hcfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
    hcfg.model.batch = 16;
    hcfg.model.input_dim = h * w * c;
    hcfg.model.classes = classes;
    hcfg.model.layers = spec.layers.len();
    hcfg.pipeline.stages = 3;
    hcfg.data.train_samples = 128;
    hcfg.data.test_samples = 32;
    let hdata = image_teacher_dataset(h, w, c, classes, &hcfg.data);

    for kind in [StrategyKind::Stashing, StrategyKind::PipelineAwareEma] {
        let backend: Backend = Arc::new(HostBackend::new());
        let mut rng = Rng::new(2);
        let mut trainer = Trainer::with_spec(backend, &hcfg, &spec, kind, &mut rng).unwrap();
        let (xb, oh) = hdata.train.batch(&(0..hcfg.model.batch).collect::<Vec<_>>());
        let prime = 24usize;
        let measure = 32usize;
        let mut feed: Vec<(Tensor, Tensor)> =
            (0..(prime + measure)).map(|_| (xb.clone(), oh.clone())).collect();
        feed.reverse();
        for _ in 0..prime {
            trainer.iteration(Some(feed.pop().expect("primed batch"))).unwrap();
        }
        let before = ALLOCS.load(Ordering::Relaxed);
        // The kernel scratch free list serves the matmul packing panels
        // and the tree-reduction dw partials: once primed, measured
        // iterations must be all hits (misses = fresh allocations only
        // while the working set warms up).
        let (scratch_hits_before, scratch_misses_before) = workers::scratch_stats();
        for _ in 0..measure {
            trainer.iteration(Some(feed.pop().expect("measured batch"))).unwrap();
        }
        let total = ALLOCS.load(Ordering::Relaxed) - before;
        let per_iter = total as f64 / measure as f64;
        let (scratch_hits, scratch_misses) = workers::scratch_stats();
        println!(
            "conv path / {}: {total} allocs over {measure} iters = {per_iter:.2}/iter \
             (scratch: +{} hits, +{} misses)",
            kind.name(),
            scratch_hits - scratch_hits_before,
            scratch_misses - scratch_misses_before
        );
        assert!(
            per_iter <= 4.0,
            "conv-path hot path regressed to {per_iter:.2} allocs/iter for {} \
             (expected (near-)zero: persistent im2col/dcols workspaces, pooled \
             chains, zero-length param-grad resizes)",
            kind.name()
        );
        assert!(
            scratch_hits > scratch_hits_before,
            "conv path / {}: packing/partial workspaces never hit the scratch pool",
            kind.name()
        );
        assert_eq!(
            scratch_misses, scratch_misses_before,
            "conv path / {}: steady-state iterations allocated fresh kernel scratch \
             (packing panels / tree-reduction partials must recycle)",
            kind.name()
        );
    }

    // ---- transformer (embedding + attention + layernorm) path ----------
    //
    // The same discipline for the transformer zoo: the fused QKV
    // projection, per-sample q/k/v/score/prob blocks and the row view
    // live in persistent op workspaces, dqkv assembles into the shared
    // scratch, the embedding scatter writes the persistent dw workspace
    // in place, and layernorm borrows scratch per row. Shapes stay under
    // the parallel-matmul threshold so the worker pool (whose task
    // boxing allocates) never engages.
    {
        use layerpipe2::data::token_teacher_dataset;

        let (seq, dm, vocab, classes) = (8usize, 8usize, 12usize, 4usize);
        let tspec = NetworkSpec {
            input: Feature::Flat(seq),
            layers: vec![
                LayerSpec::Embedding { vocab, dim: dm },
                LayerSpec::SelfAttention { seq, d_model: dm, causal: true },
                LayerSpec::LayerNorm { eps: 1e-5 },
                LayerSpec::Dense { units: seq * dm, relu: true },
                LayerSpec::SelfAttention { seq, d_model: dm, causal: true },
                LayerSpec::LayerNorm { eps: 1e-5 },
                LayerSpec::Dense { units: classes, relu: false },
            ],
            init_scale: 1.0,
        };
        let mut tcfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
        tcfg.model.batch = 16;
        tcfg.model.input_dim = seq;
        tcfg.model.classes = classes;
        tcfg.model.layers = tspec.layers.len();
        tcfg.pipeline.stages = 3;
        tcfg.data.train_samples = 128;
        tcfg.data.test_samples = 32;
        let tdata = token_teacher_dataset(seq, vocab, classes, &tcfg.data);

        for kind in [StrategyKind::Stashing, StrategyKind::PipelineAwareEma] {
            let backend: Backend = Arc::new(HostBackend::new());
            let mut rng = Rng::new(3);
            let mut trainer = Trainer::with_spec(backend, &tcfg, &tspec, kind, &mut rng).unwrap();
            let (xb, oh) = tdata.train.batch(&(0..tcfg.model.batch).collect::<Vec<_>>());
            let prime = 24usize;
            let measure = 32usize;
            let mut feed: Vec<(Tensor, Tensor)> =
                (0..(prime + measure)).map(|_| (xb.clone(), oh.clone())).collect();
            feed.reverse();
            for _ in 0..prime {
                trainer.iteration(Some(feed.pop().expect("primed batch"))).unwrap();
            }
            let before = ALLOCS.load(Ordering::Relaxed);
            for _ in 0..measure {
                trainer.iteration(Some(feed.pop().expect("measured batch"))).unwrap();
            }
            let total = ALLOCS.load(Ordering::Relaxed) - before;
            let per_iter = total as f64 / measure as f64;
            println!(
                "transformer path / {}: {total} allocs over {measure} iters = {per_iter:.2}/iter",
                kind.name()
            );
            assert!(
                per_iter <= 4.0,
                "transformer hot path regressed to {per_iter:.2} allocs/iter for {} \
                 (expected (near-)zero: persistent qkv/score/prob workspaces, shared \
                 dqkv scratch, in-place embedding scatter)",
                kind.name()
            );
        }
    }

    // ---- serving path (submit -> batch -> staged forward -> respond) ---
    //
    // The same discipline for the forward-only server: request and
    // response buffers ride the shared edge pool (clients take/recycle),
    // padded batch tensors and route tables ride circulating packets,
    // and stage ping-pong buffers resize in place — once warm, a full
    // submit->respond iteration allocates (near-)nothing anywhere in the
    // batcher/stage/collector threads. Bounded std channels are
    // array-based, so sends allocate nothing either.
    {
        use layerpipe2::layers::{Network, NetworkSpec};
        use layerpipe2::serving::{Server, ServerConfig};

        let scfg = layerpipe2::config::ModelConfig {
            batch: 8,
            input_dim: 32,
            hidden_dim: 32,
            classes: 8,
            layers: 3,
            init_scale: 1.0,
        };
        let net = Network::build(&NetworkSpec::mlp(&scfg), &mut Rng::new(4)).unwrap();
        let backend: Backend = Arc::new(HostBackend::new());
        let server = Server::start(
            backend,
            &net,
            &ServerConfig {
                max_batch: 8,
                max_wait_ticks: 0,
                queue_depth: 16,
                stages: 2,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut cl = server.client();
        let src = Tensor::randn(&[4, 32], 1.0, &mut Rng::new(5));

        let prime = 64usize;
        let measure = 64usize;
        for _ in 0..prime {
            let mut x = cl.take(&[4, 32]);
            x.copy_from(&src);
            cl.submit(x).unwrap();
            let r = cl.recv().unwrap();
            cl.recycle(r.data);
        }
        let s0 = server.stats();
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..measure {
            let mut x = cl.take(&[4, 32]);
            x.copy_from(&src);
            cl.submit(x).unwrap();
            let r = cl.recv().unwrap();
            cl.recycle(r.data);
        }
        let total = ALLOCS.load(Ordering::Relaxed) - before;
        let per_iter = total as f64 / measure as f64;
        let s1 = server.stats();
        println!(
            "serving: {total} allocs over {measure} submit->respond iters = {per_iter:.2}/iter \
             (edge pool: +{} hits, +{} misses; packets: +{})",
            s1.pool_hits - s0.pool_hits,
            s1.pool_misses - s0.pool_misses,
            s1.packets_created - s0.packets_created
        );
        assert!(
            per_iter <= 4.0,
            "serving hot path regressed to {per_iter:.2} allocs/iter (expected \
             (near-)zero: pooled request/response buffers, circulating packets, \
             in-place ping-pong stage workspaces)"
        );
        assert!(
            s1.pool_hits > s0.pool_hits,
            "serving edge pool never served a steady-state take"
        );
        assert_eq!(
            s1.pool_misses, s0.pool_misses,
            "serving edge pool allocated fresh buffers in steady state"
        );
        assert_eq!(
            s1.packets_created, s0.packets_created,
            "packet ring grew in steady state (batch tensors not circulating)"
        );
        server.shutdown().unwrap();
    }

    // ---- replica ring path (compute -> reduce -> apply) ----------------
    //
    // The same discipline for the weight ring: shard feeds come from
    // each lane's buffer pool (`take_feed`), staged gradients flatten
    // into ring-link buffers that circulate as exactly one allocation
    // per lane (take_send -> slot -> reduced copy -> put_recv), the
    // reduce tree writes a persistent output tensor, and deferred-step
    // replay clears (never drops) its pending list. A full global
    // iteration — every lane's forward + delayed backwards + reduce +
    // optimizer replay — must allocate (near-)nothing once warm.
    {
        use layerpipe2::replica::LocalRing;

        let mut rcfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
        rcfg.model.batch = 16;
        rcfg.model.input_dim = 24;
        rcfg.model.hidden_dim = 24;
        rcfg.model.classes = 4;
        rcfg.model.layers = 4;
        rcfg.pipeline.stages = 2;
        rcfg.data.train_samples = 64;
        rcfg.data.test_samples = 32;
        let rdata = teacher_dataset(&rcfg.model, &rcfg.data);

        let backend: Backend = Arc::new(HostBackend::new());
        let mut ring =
            LocalRing::new(&backend, &rcfg, None, StrategyKind::PipelineAwareEma, 2).unwrap();
        // A fixed global batch, indices allocated outside the counted
        // region — feeding data is the loader's cost.
        let idx: Vec<usize> = (0..rcfg.model.batch).collect();

        let prime = 32usize;
        let measure = 32usize;
        for _ in 0..prime {
            ring.iteration(Some(&idx), &rdata.train).unwrap();
        }
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..measure {
            ring.iteration(Some(&idx), &rdata.train).unwrap();
        }
        let total = ALLOCS.load(Ordering::Relaxed) - before;
        let per_iter = total as f64 / measure as f64;
        println!("ring: {total} allocs over {measure} global iters = {per_iter:.2}/iter");
        assert!(
            per_iter <= 4.0,
            "ring hot path regressed to {per_iter:.2} allocs/iter (expected \
             (near-)zero: pooled shard feeds, ping-pong ring links, persistent \
             reduce output, cleared-not-dropped pending steps)"
        );
        assert!(ring.lanes_bitwise_equal(), "ring lanes drifted during the alloc test");
    }

    // ---- observability on: spans and instruments must not allocate -----
    //
    // The telemetry discipline (DESIGN.md §12): instruments allocate only
    // at registration (leaked 'static inners, interned thread slots);
    // the steady-state record path — span enter/exit, counter bumps,
    // gauge moves, histogram records — is pure relaxed atomics. With the
    // span gate forced on, the dense hot path must hold the exact same
    // allocs/iter bar as with it off.
    {
        use layerpipe2::obs;
        obs::set_enabled(true);

        let mut ocfg = ExperimentConfig { epochs: 1, ..ExperimentConfig::default() };
        ocfg.data.train_samples = 256;
        ocfg.data.test_samples = 64;
        let odata = teacher_dataset(&ocfg.model, &ocfg.data);
        let backend: Backend = Arc::new(HostBackend::new());
        let mut rng = Rng::new(1);
        let mut trainer =
            Trainer::new(backend, &ocfg, StrategyKind::PipelineAwareEma, &mut rng).unwrap();
        let (xb, oh) = odata.train.batch(&(0..ocfg.model.batch).collect::<Vec<_>>());
        let prime = 48usize;
        let measure = 32usize;
        let mut feed: Vec<(Tensor, Tensor)> =
            (0..(prime + measure)).map(|_| (xb.clone(), oh.clone())).collect();
        feed.reverse();
        // Priming also registers every span label and this thread's slot,
        // so the counted region sees only the record path.
        for _ in 0..prime {
            trainer.iteration(Some(feed.pop().expect("primed batch"))).unwrap();
        }
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..measure {
            trainer.iteration(Some(feed.pop().expect("measured batch"))).unwrap();
        }
        let total = ALLOCS.load(Ordering::Relaxed) - before;
        let per_iter = total as f64 / measure as f64;
        println!("obs on: {total} allocs over {measure} iters = {per_iter:.2}/iter");
        assert!(
            per_iter <= 4.0,
            "span-instrumented hot path regressed to {per_iter:.2} allocs/iter \
             (spans must be clock reads + relaxed atomics, no allocation)"
        );

        // The instruments themselves: registration may allocate (once),
        // the record path must allocate exactly nothing.
        let c = obs::counter("alloc_test/ctr");
        let g = obs::gauge("alloc_test/gauge");
        let h = obs::hist("alloc_test/hist");
        c.inc();
        g.set(1);
        h.record_ns(10);
        let before = ALLOCS.load(Ordering::Relaxed);
        for i in 0..1000u64 {
            c.add(1);
            g.add(1);
            h.record_ns(i * 37);
        }
        let grew = ALLOCS.load(Ordering::Relaxed) - before;
        assert_eq!(
            grew, 0,
            "registered instruments allocated on the record path ({grew} allocations \
             over 3000 ops — counters/gauges/histograms must be pure atomics)"
        );
    }
}
