//! Equivalence gate for the zero-allocation kernel substrate.
//!
//! Two contracts are load-bearing for the hot-path refactor:
//!
//! 1. Every `_into` kernel is **bitwise identical** to its allocating
//!    counterpart, on random shapes, even when the output buffer arrives
//!    dirty (the BufferPool hands out recycled storage with stale
//!    contents).
//! 2. The persistent-WorkerPool matmuls are **bit-stable across worker
//!    counts**: the row partition depends on the thread count, the
//!    per-row accumulation order never does.

use layerpipe2::tensor::{self, Tensor};
use layerpipe2::util::Rng;

/// A deliberately dirty output buffer (wrong shape, garbage contents).
fn dirty(rng: &mut Rng) -> Tensor {
    Tensor::randn(&[1 + rng.index(5), 1 + rng.index(5)], 9.0, rng)
}

#[test]
fn into_kernels_match_allocating_bitwise_on_random_shapes() {
    let mut rng = Rng::new(2024);
    for case in 0..12 {
        let m = 1 + rng.index(48);
        let k = 1 + rng.index(48);
        let n = 1 + rng.index(48);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);

        let mut out = dirty(&mut rng);
        tensor::matmul_into(&a, &b, &mut out);
        assert_eq!(out, tensor::matmul(&a, &b), "case {case}: matmul");

        let mut out = dirty(&mut rng);
        tensor::matmul_nt_into(&a, &bt, &mut out);
        assert_eq!(out, tensor::matmul_nt(&a, &bt), "case {case}: matmul_nt");

        let a2 = Tensor::randn(&[k, m], 1.0, &mut rng);
        let b2 = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut out = dirty(&mut rng);
        tensor::matmul_tn_into(&a2, &b2, &mut out);
        assert_eq!(out, tensor::matmul_tn(&a2, &b2), "case {case}: matmul_tn");

        let bias = Tensor::randn(&[n], 0.5, &mut rng);
        let x = Tensor::randn(&[m, n], 1.0, &mut rng);
        let mut out = dirty(&mut rng);
        tensor::add_bias_into(&x, &bias, &mut out);
        assert_eq!(out, tensor::add_bias(&x, &bias), "case {case}: add_bias");

        let mut out = dirty(&mut rng);
        tensor::relu_into(&x, &mut out);
        assert_eq!(out, tensor::relu(&x), "case {case}: relu");

        let y = tensor::relu(&x);
        let dy = Tensor::randn(&[m, n], 1.0, &mut rng);
        let mut out = dirty(&mut rng);
        tensor::relu_grad_into(&y, &dy, &mut out);
        assert_eq!(out, tensor::relu_grad(&y, &dy), "case {case}: relu_grad");

        let mut out = dirty(&mut rng);
        tensor::col_sum_into(&x, &mut out);
        assert_eq!(out, tensor::col_sum(&x), "case {case}: col_sum");

        let mut out = dirty(&mut rng);
        tensor::softmax_rows_into(&x, &mut out);
        assert_eq!(out, tensor::softmax_rows(&x), "case {case}: softmax_rows");

        // Loss kernel: loss, gradient and correct-count all bitwise.
        let classes = 2 + rng.index(9);
        let logits = Tensor::randn(&[m, classes], 2.0, &mut rng);
        let mut onehot = Tensor::zeros(&[m, classes]);
        for i in 0..m {
            let label = rng.index(classes);
            onehot.set2(i, label, 1.0);
        }
        let (loss_ref, dl_ref, correct_ref) = tensor::softmax_xent_onehot(&logits, &onehot);
        let mut dl = dirty(&mut rng);
        let (loss, correct) = tensor::softmax_xent_onehot_into(&logits, &onehot, &mut dl);
        assert_eq!(loss, loss_ref, "case {case}: xent loss");
        assert_eq!(dl, dl_ref, "case {case}: xent gradient");
        assert_eq!(correct, correct_ref, "case {case}: xent correct");
    }
}

#[test]
fn fused_backward_epilogue_matches_unfused_composition() {
    let mut rng = Rng::new(31);
    for case in 0..8 {
        let m = 1 + rng.index(32);
        let n = 1 + rng.index(32);
        let y = tensor::relu(&Tensor::randn(&[m, n], 1.0, &mut rng));
        let dy = Tensor::randn(&[m, n], 1.0, &mut rng);
        let (mut dz, mut db) = (dirty(&mut rng), dirty(&mut rng));
        tensor::relu_grad_col_sum_into(&y, &dy, &mut dz, &mut db);
        let dz_ref = tensor::relu_grad(&y, &dy);
        assert_eq!(dz, dz_ref, "case {case}: fused dz");
        assert_eq!(db, tensor::col_sum(&dz_ref), "case {case}: fused db");
    }
}

#[test]
fn worker_pool_matmul_is_bit_stable_across_thread_counts() {
    let mut rng = Rng::new(7);
    // Above PAR_MIN_MADDS (160·96·96 ≈ 1.5M madds) so the pooled row
    // split actually engages for threads > 1.
    let (m, k, n) = (160usize, 96usize, 96usize);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let mut reference = Tensor::empty();
    tensor::matmul_into_with_threads(&a, &b, &mut reference, 1);
    for threads in [2, 3, 4, 7, 16] {
        let mut out = Tensor::empty();
        tensor::matmul_into_with_threads(&a, &b, &mut out, threads);
        assert_eq!(out, reference, "matmul diverged at threads={threads}");
    }

    let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
    let mut nt_reference = Tensor::empty();
    tensor::matmul_nt_into_with_threads(&a, &bt, &mut nt_reference, 1);
    for threads in [2, 3, 4, 7, 16] {
        let mut out = Tensor::empty();
        tensor::matmul_nt_into_with_threads(&a, &bt, &mut out, threads);
        assert_eq!(out, nt_reference, "matmul_nt diverged at threads={threads}");
    }
}

#[test]
fn worker_pool_survives_concurrent_submitters() {
    // Pipeline stage threads share the global pool: concurrent matmuls
    // from several OS threads must all come out bit-identical to the
    // serial reference.
    let mut rng = Rng::new(42);
    let (m, k, n) = (160usize, 96usize, 96usize);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let mut reference = Tensor::empty();
    tensor::matmul_into_with_threads(&a, &b, &mut reference, 1);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let (a, b, reference) = (&a, &b, &reference);
            scope.spawn(move || {
                for _ in 0..8 {
                    let mut out = Tensor::empty();
                    tensor::matmul_into(a, b, &mut out);
                    assert_eq!(&out, reference);
                }
            });
        }
    });
}
