//! Equivalence gate for the zero-allocation kernel substrate.
//!
//! Two contracts are load-bearing for the hot-path refactor:
//!
//! 1. Every `_into` kernel is **bitwise identical** to its allocating
//!    counterpart, on random shapes, even when the output buffer arrives
//!    dirty (the BufferPool hands out recycled storage with stale
//!    contents).
//! 2. The persistent-WorkerPool matmuls are **bit-stable across worker
//!    counts**: the row partition depends on the thread count, the
//!    per-row accumulation order never does.

use layerpipe2::tensor::{self, Tensor};
use layerpipe2::util::Rng;

/// A deliberately dirty output buffer (wrong shape, garbage contents).
fn dirty(rng: &mut Rng) -> Tensor {
    Tensor::randn(&[1 + rng.index(5), 1 + rng.index(5)], 9.0, rng)
}

#[test]
fn into_kernels_match_allocating_bitwise_on_random_shapes() {
    let mut rng = Rng::new(2024);
    for case in 0..12 {
        let m = 1 + rng.index(48);
        let k = 1 + rng.index(48);
        let n = 1 + rng.index(48);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);

        let mut out = dirty(&mut rng);
        tensor::matmul_into(&a, &b, &mut out);
        assert_eq!(out, tensor::matmul(&a, &b), "case {case}: matmul");

        let mut out = dirty(&mut rng);
        tensor::matmul_nt_into(&a, &bt, &mut out);
        assert_eq!(out, tensor::matmul_nt(&a, &bt), "case {case}: matmul_nt");

        let a2 = Tensor::randn(&[k, m], 1.0, &mut rng);
        let b2 = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut out = dirty(&mut rng);
        tensor::matmul_tn_into(&a2, &b2, &mut out);
        assert_eq!(out, tensor::matmul_tn(&a2, &b2), "case {case}: matmul_tn");

        let bias = Tensor::randn(&[n], 0.5, &mut rng);
        let x = Tensor::randn(&[m, n], 1.0, &mut rng);
        let mut out = dirty(&mut rng);
        tensor::add_bias_into(&x, &bias, &mut out);
        assert_eq!(out, tensor::add_bias(&x, &bias), "case {case}: add_bias");

        let mut out = dirty(&mut rng);
        tensor::relu_into(&x, &mut out);
        assert_eq!(out, tensor::relu(&x), "case {case}: relu");

        let y = tensor::relu(&x);
        let dy = Tensor::randn(&[m, n], 1.0, &mut rng);
        let mut out = dirty(&mut rng);
        tensor::relu_grad_into(&y, &dy, &mut out);
        assert_eq!(out, tensor::relu_grad(&y, &dy), "case {case}: relu_grad");

        let mut out = dirty(&mut rng);
        tensor::col_sum_into(&x, &mut out);
        assert_eq!(out, tensor::col_sum(&x), "case {case}: col_sum");

        let mut out = dirty(&mut rng);
        tensor::softmax_rows_into(&x, &mut out);
        assert_eq!(out, tensor::softmax_rows(&x), "case {case}: softmax_rows");

        // Loss kernel: loss, gradient and correct-count all bitwise.
        let classes = 2 + rng.index(9);
        let logits = Tensor::randn(&[m, classes], 2.0, &mut rng);
        let mut onehot = Tensor::zeros(&[m, classes]);
        for i in 0..m {
            let label = rng.index(classes);
            onehot.set2(i, label, 1.0);
        }
        let (loss_ref, dl_ref, correct_ref) = tensor::softmax_xent_onehot(&logits, &onehot);
        let mut dl = dirty(&mut rng);
        let (loss, correct) = tensor::softmax_xent_onehot_into(&logits, &onehot, &mut dl);
        assert_eq!(loss, loss_ref, "case {case}: xent loss");
        assert_eq!(dl, dl_ref, "case {case}: xent gradient");
        assert_eq!(correct, correct_ref, "case {case}: xent correct");
    }
}

#[test]
fn fused_backward_epilogue_matches_unfused_composition() {
    let mut rng = Rng::new(31);
    for case in 0..8 {
        let m = 1 + rng.index(32);
        let n = 1 + rng.index(32);
        let y = tensor::relu(&Tensor::randn(&[m, n], 1.0, &mut rng));
        let dy = Tensor::randn(&[m, n], 1.0, &mut rng);
        let (mut dz, mut db) = (dirty(&mut rng), dirty(&mut rng));
        tensor::relu_grad_col_sum_into(&y, &dy, &mut dz, &mut db);
        let dz_ref = tensor::relu_grad(&y, &dy);
        assert_eq!(dz, dz_ref, "case {case}: fused dz");
        assert_eq!(db, tensor::col_sum(&dz_ref), "case {case}: fused db");
    }
}

#[test]
fn worker_pool_matmul_is_bit_stable_across_thread_counts() {
    let mut rng = Rng::new(7);
    // Above PAR_MIN_MADDS (160·96·96 ≈ 1.5M madds) so the pooled row
    // split actually engages for threads > 1.
    let (m, k, n) = (160usize, 96usize, 96usize);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let mut reference = Tensor::empty();
    tensor::matmul_into_with_threads(&a, &b, &mut reference, 1);
    for threads in [2, 3, 4, 7, 16] {
        let mut out = Tensor::empty();
        tensor::matmul_into_with_threads(&a, &b, &mut out, threads);
        assert_eq!(out, reference, "matmul diverged at threads={threads}");
    }

    let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
    let mut nt_reference = Tensor::empty();
    tensor::matmul_nt_into_with_threads(&a, &bt, &mut nt_reference, 1);
    for threads in [2, 3, 4, 7, 16] {
        let mut out = Tensor::empty();
        tensor::matmul_nt_into_with_threads(&a, &bt, &mut out, threads);
        assert_eq!(out, nt_reference, "matmul_nt diverged at threads={threads}");
    }
}

#[test]
fn packed_matmuls_are_bitwise_equal_to_unpacked_reference() {
    // The panel packing and register tiling are pure layout/scheduling
    // changes: per output element the multiply-add order is ascending k,
    // exactly the naive triple loop — so the production kernels must
    // match the kept-for-tests scalar references BITWISE, including on
    // dirty pooled output buffers.
    let mut rng = Rng::new(515);
    for case in 0..12 {
        // Mix panel-edge shapes (n % 32 ≠ 0), k=1, and one parallel-path
        // shape at the end.
        let (m, k, n) = if case == 11 {
            (160, 96, 96)
        } else {
            (1 + rng.index(70), 1 + rng.index(70), 1 + rng.index(70))
        };
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut out = dirty(&mut rng);
        tensor::matmul_into(&a, &b, &mut out);
        assert_eq!(out, tensor::reference::matmul(&a, &b), "case {case}: packed matmul");

        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
        let mut out = dirty(&mut rng);
        tensor::matmul_nt_into(&a, &bt, &mut out);
        assert_eq!(out, tensor::reference::matmul_nt(&a, &bt), "case {case}: tiled matmul_nt");
    }
}

#[test]
fn tree_reduction_matmul_tn_is_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(616);
    // Shapes with several TN_CHUNK(=64)-row chunks: one below the
    // parallel threshold (serial must already follow the tree order) and
    // one above it (pooled chunk tasks engage). Small std keeps the
    // naive-reference tolerance meaningful at these accumulation depths.
    for (r, m, n) in [(200usize, 48usize, 40usize), (2048, 48, 48)] {
        let a = Tensor::randn(&[r, m], 0.1, &mut rng);
        let b = Tensor::randn(&[r, n], 0.1, &mut rng);
        let mut reference = Tensor::empty();
        tensor::matmul_tn_into_with_threads(&a, &b, &mut reference, 1);
        for threads in 2..=8 {
            let mut out = dirty(&mut rng);
            tensor::matmul_tn_into_with_threads(&a, &b, &mut out, threads);
            assert_eq!(out, reference, "matmul_tn diverged at r={r} threads={threads}");
        }
        // The default entry point (pool-sized) must sit on the same tree.
        let mut auto = dirty(&mut rng);
        tensor::matmul_tn_into(&a, &b, &mut auto);
        assert_eq!(auto, reference, "matmul_tn auto path diverged at r={r}");
        // Tolerance (never bitwise once r > TN_CHUNK — the tree
        // legitimately reassociates) vs the old sequential order.
        let naive = tensor::reference::matmul_tn(&a, &b);
        assert!(
            reference.max_abs_diff(&naive) < 1e-5,
            "r={r}: tree drifted {} from the sequential reference",
            reference.max_abs_diff(&naive)
        );
    }
}

#[test]
fn chunked_epilogue_reduction_matches_composition() {
    // Above the epilogue parallel threshold (rows·n ≥ 2^20) the fused
    // mask+col-sum kernel switches to fixed 256-row chunks with an
    // ascending partial combine. dz is per-row (bitwise); db changes
    // summation order vs the single pass, so compare with tolerance —
    // and re-running must be exactly reproducible (fixed geometry).
    let mut rng = Rng::new(717);
    let (rows, n) = (4099usize, 260usize); // ≥ 2^20 elements, ragged tail
    let y = tensor::relu(&Tensor::randn(&[rows, n], 1.0, &mut rng));
    let dy = Tensor::randn(&[rows, n], 1.0, &mut rng);
    let (mut dz, mut db) = (dirty(&mut rng), dirty(&mut rng));
    tensor::relu_grad_col_sum_into(&y, &dy, &mut dz, &mut db);
    assert_eq!(dz, tensor::relu_grad(&y, &dy), "chunked dz must stay per-row exact");
    let db_ref = tensor::col_sum(&tensor::relu_grad(&y, &dy));
    assert!(
        db.max_abs_diff(&db_ref) < 1e-3,
        "chunked db drifted {} from the composition",
        db.max_abs_diff(&db_ref)
    );
    let (mut dz2, mut db2) = (dirty(&mut rng), dirty(&mut rng));
    tensor::relu_grad_col_sum_into(&y, &dy, &mut dz2, &mut db2);
    assert_eq!(db, db2, "chunked reduction must be exactly reproducible");
}

#[test]
fn worker_pool_survives_concurrent_submitters() {
    // Pipeline stage threads share the global pool: concurrent matmuls
    // from several OS threads must all come out bit-identical to the
    // serial reference.
    let mut rng = Rng::new(42);
    let (m, k, n) = (160usize, 96usize, 96usize);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let mut reference = Tensor::empty();
    tensor::matmul_into_with_threads(&a, &b, &mut reference, 1);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let (a, b, reference) = (&a, &b, &reference);
            scope.spawn(move || {
                for _ in 0..8 {
                    let mut out = Tensor::empty();
                    tensor::matmul_into(a, b, &mut out);
                    assert_eq!(&out, reference);
                }
            });
        }
    });
}
