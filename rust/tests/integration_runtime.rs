//! Integration: PJRT runtime vs host-tensor oracles, over real artifacts.
//!
//! These tests need the `pjrt` feature *and* `make artifacts` (the
//! `small` preset manifest in `artifacts/`). Without the feature the
//! whole file compiles away; without the artifacts each test skips with
//! a note, so `cargo test -q` stays green on a clean checkout. They
//! prove the full AOT bridge: jax/pallas → HLO text → rust compile →
//! execute → numbers match the from-scratch host ops.
#![cfg(feature = "pjrt")]

use layerpipe2::backend::artifacts_present;
use layerpipe2::config::ModelConfig;
use layerpipe2::model::{LayerRole, Mlp};
use layerpipe2::runtime::Engine;
use layerpipe2::tensor::{self, Tensor};
use layerpipe2::testing::assert_allclose;
use layerpipe2::util::Rng;
use std::sync::OnceLock;

/// The compiled engine, or `None` when no artifacts are checked out.
fn engine() -> Option<&'static Engine> {
    static ENGINE: OnceLock<Option<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            if !artifacts_present("artifacts") {
                return None;
            }
            Some(Engine::load("artifacts").expect("artifacts present but unloadable"))
        })
        .as_ref()
}

/// Skip-or-run shim: artifact tests are opt-in by checkout state.
macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => {
                eprintln!("skipping: no artifacts/ (run `make artifacts` for the PJRT tests)");
                return;
            }
        }
    };
}

fn model_cfg(engine: &Engine) -> ModelConfig {
    engine.manifest().model.to_model_config()
}

#[test]
fn manifest_matches_small_preset() {
    let engine = require_engine!();
    let m = engine.manifest();
    assert_eq!(m.preset, "small");
    assert_eq!(m.model.batch, 32);
    assert_eq!(m.model.layers, 8);
    assert_eq!(m.entries.len(), 9); // incl. ablation_fwd_hid_jnp
}

#[test]
fn dense_fwd_matches_host_oracle() {
    let engine = require_engine!();
    let cfg = model_cfg(engine);
    let mut rng = Rng::new(42);
    let x = Tensor::randn(&[cfg.batch, cfg.hidden_dim], 1.0, &mut rng);
    let w = Tensor::randn(&[cfg.hidden_dim, cfg.hidden_dim], 0.2, &mut rng);
    let b = Tensor::randn(&[cfg.hidden_dim], 0.1, &mut rng);
    let got = engine.run("dense_fwd_hid", &[&x, &w, &b]).unwrap();
    let want = tensor::relu(&tensor::add_bias(&tensor::matmul(&x, &w), &b));
    assert_allclose(got[0].data(), want.data(), 1e-4, 1e-4, "dense_fwd_hid");
}

#[test]
fn dense_bwd_matches_host_oracle() {
    let engine = require_engine!();
    let cfg = model_cfg(engine);
    let mut rng = Rng::new(43);
    let h = cfg.hidden_dim;
    let x = Tensor::randn(&[cfg.batch, h], 1.0, &mut rng);
    let w = Tensor::randn(&[h, h], 0.2, &mut rng);
    let b = Tensor::randn(&[h], 0.1, &mut rng);
    let y = tensor::relu(&tensor::add_bias(&tensor::matmul(&x, &w), &b));
    let dy = Tensor::randn(&[cfg.batch, h], 1.0, &mut rng);

    let got = engine.run("dense_bwd_hid", &[&x, &y, &w, &dy]).unwrap();
    let dz = tensor::relu_grad(&y, &dy);
    let want_dx = tensor::matmul_nt(&dz, &w);
    let want_dw = tensor::matmul_tn(&x, &dz);
    let want_db = tensor::col_sum(&dz);
    assert_allclose(got[0].data(), want_dx.data(), 1e-3, 1e-3, "dx");
    assert_allclose(got[1].data(), want_dw.data(), 1e-3, 1e-3, "dw");
    assert_allclose(got[2].data(), want_db.data(), 1e-3, 1e-3, "db");
}

#[test]
fn loss_grad_matches_host_oracle() {
    let engine = require_engine!();
    let cfg = model_cfg(engine);
    let mut rng = Rng::new(44);
    let logits = Tensor::randn(&[cfg.batch, cfg.classes], 2.0, &mut rng);
    let labels: Vec<usize> = (0..cfg.batch).map(|_| rng.index(cfg.classes)).collect();
    let mut onehot = Tensor::zeros(&[cfg.batch, cfg.classes]);
    for (i, &l) in labels.iter().enumerate() {
        onehot.set2(i, l, 1.0);
    }
    let got = engine.run("loss_grad", &[&logits, &onehot]).unwrap();
    let (want_loss, want_dl, want_correct) = tensor::softmax_xent(&logits, &labels);
    assert!((got[0].data()[0] - want_loss).abs() < 1e-4, "loss");
    assert_allclose(got[1].data(), want_dl.data(), 1e-5, 1e-4, "dlogits");
    assert_eq!(got[2].data()[0] as usize, want_correct, "correct count");
}

#[test]
fn fwd_full_equals_per_layer_chain() {
    let engine = require_engine!();
    let cfg = model_cfg(engine);
    let mut rng = Rng::new(45);
    let mlp = Mlp::init(&cfg, &mut rng);
    let x = Tensor::randn(&[cfg.batch, cfg.input_dim], 1.0, &mut rng);

    // Through the backend seam: fused artifact vs per-layer artifacts.
    let backend = layerpipe2::backend::PjrtBackend::from_engine(
        Engine::load("artifacts").expect("second engine for backend test"),
    );
    let fused = mlp.forward_full(&backend, &x).unwrap();
    let mut h = x;
    for l in 0..cfg.layers {
        h = mlp.forward_layer(&backend, l, &h).unwrap();
    }
    assert_allclose(fused.data(), h.data(), 1e-3, 1e-3, "fused vs chain");
}

#[test]
fn pjrt_and_host_backends_agree_on_a_layer() {
    use layerpipe2::backend::{Exec, HostBackend};
    let engine = require_engine!();
    let cfg = model_cfg(engine);
    let mut rng = Rng::new(48);
    let x = Tensor::randn(&[cfg.batch, cfg.hidden_dim], 1.0, &mut rng);
    let w = Tensor::randn(&[cfg.hidden_dim, cfg.hidden_dim], 0.2, &mut rng);
    let b = Tensor::randn(&[cfg.hidden_dim], 0.1, &mut rng);
    let host = HostBackend::new();
    let host_y = host.forward(LayerRole::Hidden, &x, &w, &b).unwrap();
    let pjrt_y = engine.run("dense_fwd_hid", &[&x, &w, &b]).unwrap().remove(0);
    assert_allclose(pjrt_y.data(), host_y.data(), 1e-4, 1e-4, "backend parity");
}

#[test]
fn layer_roles_dispatch_correct_artifacts() {
    let engine = require_engine!();
    let cfg = model_cfg(engine);
    let mut rng = Rng::new(46);
    let mlp = Mlp::init(&cfg, &mut rng);
    assert_eq!(mlp.layers[0].role, LayerRole::Input);
    assert_eq!(mlp.layers[cfg.layers - 1].role, LayerRole::Output);
    // Input layer consumes [B, D]; output produces [B, C].
    let x = Tensor::randn(&[cfg.batch, cfg.input_dim], 1.0, &mut rng);
    let y0 = engine
        .run("dense_fwd_in", &[&x, &mlp.layers[0].w, &mlp.layers[0].b])
        .unwrap()
        .remove(0);
    assert_eq!(y0.shape(), &[cfg.batch, cfg.hidden_dim]);
}

#[test]
fn shape_mismatch_is_rejected_not_ub() {
    let engine = require_engine!();
    let cfg = model_cfg(engine);
    let mut rng = Rng::new(47);
    let wrong = Tensor::randn(&[cfg.batch, cfg.hidden_dim + 1], 1.0, &mut rng);
    let w = Tensor::randn(&[cfg.hidden_dim, cfg.hidden_dim], 1.0, &mut rng);
    let b = Tensor::randn(&[cfg.hidden_dim], 1.0, &mut rng);
    let err = engine.run("dense_fwd_hid", &[&wrong, &w, &b]);
    assert!(err.is_err(), "shape mismatch must error");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("shape"), "useful message, got: {msg}");
}

#[test]
fn unknown_artifact_is_rejected() {
    let engine = require_engine!();
    assert!(engine.run("nonexistent", &[]).is_err());
}

#[test]
fn relu_epilogue_is_active_in_artifact() {
    // All-negative pre-activations → exactly zero output (fused ReLU).
    let engine = require_engine!();
    let cfg = model_cfg(engine);
    let x = Tensor::from_vec(
        &[cfg.batch, cfg.hidden_dim],
        vec![1.0; cfg.batch * cfg.hidden_dim],
    );
    let mut w = Tensor::zeros(&[cfg.hidden_dim, cfg.hidden_dim]);
    for v in w.data_mut().iter_mut() {
        *v = -0.1;
    }
    let b = Tensor::zeros(&[cfg.hidden_dim]);
    let y = engine.run("dense_fwd_hid", &[&x, &w, &b]).unwrap();
    assert!(y[0].data().iter().all(|&v| v == 0.0));
}
