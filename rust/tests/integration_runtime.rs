//! Integration: PJRT runtime vs host-tensor oracles, over real artifacts.
//!
//! These tests require `make artifacts` (the `small` preset manifest in
//! `artifacts/`). They prove the full AOT bridge: jax/pallas → HLO text →
//! rust compile → execute → numbers match the from-scratch host ops.

use layerpipe2::config::ModelConfig;
use layerpipe2::model::{LayerRole, Mlp};
use layerpipe2::runtime::Engine;
use layerpipe2::tensor::{self, Tensor};
use layerpipe2::testing::assert_allclose;
use layerpipe2::util::Rng;
use std::sync::OnceLock;

fn engine() -> &'static Engine {
    static ENGINE: OnceLock<Engine> = OnceLock::new();
    ENGINE.get_or_init(|| {
        Engine::load("artifacts").expect("run `make artifacts` before cargo test")
    })
}

fn model_cfg() -> ModelConfig {
    let m = &engine().manifest().model;
    ModelConfig {
        batch: m.batch,
        input_dim: m.input_dim,
        hidden_dim: m.hidden_dim,
        classes: m.classes,
        layers: m.layers,
        init_scale: 1.0,
    }
}

#[test]
fn manifest_matches_small_preset() {
    let m = engine().manifest();
    assert_eq!(m.preset, "small");
    assert_eq!(m.model.batch, 32);
    assert_eq!(m.model.layers, 8);
    assert_eq!(m.entries.len(), 9); // incl. ablation_fwd_hid_jnp
}

#[test]
fn dense_fwd_matches_host_oracle() {
    let cfg = model_cfg();
    let mut rng = Rng::new(42);
    let x = Tensor::randn(&[cfg.batch, cfg.hidden_dim], 1.0, &mut rng);
    let w = Tensor::randn(&[cfg.hidden_dim, cfg.hidden_dim], 0.2, &mut rng);
    let b = Tensor::randn(&[cfg.hidden_dim], 0.1, &mut rng);
    let got = engine().run("dense_fwd_hid", &[&x, &w, &b]).unwrap();
    let want = tensor::relu(&tensor::add_bias(&tensor::matmul(&x, &w), &b));
    assert_allclose(got[0].data(), want.data(), 1e-4, 1e-4, "dense_fwd_hid");
}

#[test]
fn dense_bwd_matches_host_oracle() {
    let cfg = model_cfg();
    let mut rng = Rng::new(43);
    let h = cfg.hidden_dim;
    let x = Tensor::randn(&[cfg.batch, h], 1.0, &mut rng);
    let w = Tensor::randn(&[h, h], 0.2, &mut rng);
    let b = Tensor::randn(&[h], 0.1, &mut rng);
    let y = tensor::relu(&tensor::add_bias(&tensor::matmul(&x, &w), &b));
    let dy = Tensor::randn(&[cfg.batch, h], 1.0, &mut rng);

    let got = engine().run("dense_bwd_hid", &[&x, &y, &w, &dy]).unwrap();
    let dz = tensor::relu_grad(&y, &dy);
    let want_dx = tensor::matmul(&dz, &tensor::transpose(&w));
    let want_dw = tensor::matmul(&tensor::transpose(&x), &dz);
    assert_allclose(got[0].data(), want_dx.data(), 1e-3, 1e-3, "dx");
    assert_allclose(got[1].data(), want_dw.data(), 1e-3, 1e-3, "dw");
    // db = column sums of dz
    let mut want_db = Tensor::zeros(&[h]);
    for r in 0..cfg.batch {
        for c in 0..h {
            want_db.data_mut()[c] += dz.at2(r, c);
        }
    }
    assert_allclose(got[2].data(), want_db.data(), 1e-3, 1e-3, "db");
}

#[test]
fn loss_grad_matches_host_oracle() {
    let cfg = model_cfg();
    let mut rng = Rng::new(44);
    let logits = Tensor::randn(&[cfg.batch, cfg.classes], 2.0, &mut rng);
    let labels: Vec<usize> = (0..cfg.batch).map(|_| rng.index(cfg.classes)).collect();
    let mut onehot = Tensor::zeros(&[cfg.batch, cfg.classes]);
    for (i, &l) in labels.iter().enumerate() {
        onehot.set2(i, l, 1.0);
    }
    let got = engine().run("loss_grad", &[&logits, &onehot]).unwrap();
    let (want_loss, want_dl, want_correct) = tensor::softmax_xent(&logits, &labels);
    assert!((got[0].data()[0] - want_loss).abs() < 1e-4, "loss");
    assert_allclose(got[1].data(), want_dl.data(), 1e-5, 1e-4, "dlogits");
    assert_eq!(got[2].data()[0] as usize, want_correct, "correct count");
}

#[test]
fn fwd_full_equals_per_layer_chain() {
    let cfg = model_cfg();
    let mut rng = Rng::new(45);
    let mlp = Mlp::init(&cfg, &mut rng);
    let x = Tensor::randn(&[cfg.batch, cfg.input_dim], 1.0, &mut rng);

    let fused = mlp.forward_full(engine(), &x).unwrap();
    let mut h = x;
    for l in 0..cfg.layers {
        h = mlp.forward_layer(engine(), l, &h).unwrap();
    }
    assert_allclose(fused.data(), h.data(), 1e-3, 1e-3, "fused vs chain");
}

#[test]
fn layer_roles_dispatch_correct_artifacts() {
    let cfg = model_cfg();
    let mut rng = Rng::new(46);
    let mlp = Mlp::init(&cfg, &mut rng);
    assert_eq!(mlp.layers[0].role, LayerRole::Input);
    assert_eq!(mlp.layers[cfg.layers - 1].role, LayerRole::Output);
    // Input layer consumes [B, D]; output produces [B, C].
    let x = Tensor::randn(&[cfg.batch, cfg.input_dim], 1.0, &mut rng);
    let y0 = mlp.forward_layer(engine(), 0, &x).unwrap();
    assert_eq!(y0.shape(), &[cfg.batch, cfg.hidden_dim]);
    let logits = mlp
        .forward_layer(engine(), cfg.layers - 1, &y0)
        .unwrap();
    assert_eq!(logits.shape(), &[cfg.batch, cfg.classes]);
}

#[test]
fn shape_mismatch_is_rejected_not_ub() {
    let cfg = model_cfg();
    let mut rng = Rng::new(47);
    let wrong = Tensor::randn(&[cfg.batch, cfg.hidden_dim + 1], 1.0, &mut rng);
    let w = Tensor::randn(&[cfg.hidden_dim, cfg.hidden_dim], 1.0, &mut rng);
    let b = Tensor::randn(&[cfg.hidden_dim], 1.0, &mut rng);
    let err = engine().run("dense_fwd_hid", &[&wrong, &w, &b]);
    assert!(err.is_err(), "shape mismatch must error");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("shape"), "useful message, got: {msg}");
}

#[test]
fn unknown_artifact_is_rejected() {
    assert!(engine().run("nonexistent", &[]).is_err());
}

#[test]
fn relu_epilogue_is_active_in_artifact() {
    // All-negative pre-activations → exactly zero output (fused ReLU).
    let cfg = model_cfg();
    let x = Tensor::from_vec(
        &[cfg.batch, cfg.hidden_dim],
        vec![1.0; cfg.batch * cfg.hidden_dim],
    );
    let mut w = Tensor::zeros(&[cfg.hidden_dim, cfg.hidden_dim]);
    for v in w.data_mut().iter_mut() {
        *v = -0.1;
    }
    let b = Tensor::zeros(&[cfg.hidden_dim]);
    let y = engine().run("dense_fwd_hid", &[&x, &w, &b]).unwrap();
    assert!(y[0].data().iter().all(|&v| v == 0.0));
}
