//! Integration: the batched inference serving subsystem under real
//! concurrency.
//!
//! The acceptance bar of the serving PR:
//!  - N client threads x M requests each through the live server, every
//!    response **bitwise equal** to the single-threaded sequential
//!    forward oracle (`Network::forward_full`) — for a dense and a
//!    conv+pool+dense network — with per-client response order
//!    preserved;
//!  - checkpoint hot-reload mid-traffic: every response is attributable
//!    to exactly one weight epoch (its payload matches that epoch's
//!    oracle bitwise — a torn read would match none), versions observed
//!    by a client never go backwards, and a restore-from-disk roundtrip
//!    serves identically to the in-memory network it was saved from.
//!
//! Worker-count note: the kernels under the serving stages are the PR 4
//! family, bit-stable across `LAYERPIPE2_WORKERS` by construction
//! (`tests/kernel_into_equivalence.rs` asserts it kernel-by-kernel), so
//! oracle equivalence here holds for every worker count — this file
//! runs under whatever the environment selects and stays green.
//!
//! Everything runs on the host backend so a clean checkout exercises
//! the full machinery.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::layers::{Feature, LayerSpec, Network, NetworkSpec};
use layerpipe2::model::checkpoint;
use layerpipe2::serving::{drive_and_verify, Server, ServerConfig};
use layerpipe2::tensor::Tensor;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn host() -> Backend {
    Arc::new(HostBackend::new())
}

fn dense_spec() -> NetworkSpec {
    NetworkSpec {
        input: Feature::Flat(20),
        layers: vec![
            LayerSpec::Dense { units: 24, relu: true },
            LayerSpec::Dense { units: 24, relu: true },
            LayerSpec::Dense { units: 16, relu: true },
            LayerSpec::Dense { units: 5, relu: false },
        ],
        init_scale: 1.0,
    }
}

fn conv_spec() -> NetworkSpec {
    NetworkSpec {
        input: Feature::Image { h: 6, w: 6, c: 1 },
        layers: vec![
            LayerSpec::Conv2d { out_c: 3, k: 3, stride: 1, pad: 1, relu: true },
            LayerSpec::MaxPool2d { k: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 12, relu: true },
            LayerSpec::Dense { units: 4, relu: false },
        ],
        init_scale: 1.0,
    }
}

/// N client threads x M requests of varying row counts; every response
/// must be bitwise equal to the sequential oracle, in submit order.
fn stress_one(name: &str, spec: &NetworkSpec, stages: usize) {
    let net = Network::build(spec, &mut Rng::new(11)).unwrap();
    let in_dim = net.input_dim();
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait_ticks: 1,
        queue_depth: 32,
        stages,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &net, &cfg).unwrap();

    let n_clients = 4usize;
    let m = 32usize;
    let be = HostBackend::new();
    let mut oracle = net.snapshot().unwrap();

    let mut handles = Vec::new();
    for c in 0..n_clients {
        // Deterministic per-client payloads with varying row counts, and
        // their single-threaded oracle outputs, computed up front.
        let mut rng = Rng::new(1000 + c as u64);
        let inputs: Vec<Tensor> = (0..m)
            .map(|i| Tensor::randn(&[1 + (c + 3 * i) % cfg.max_batch, in_dim], 1.0, &mut rng))
            .collect();
        // Single weight epoch: the harness's epoch check pins every
        // response to version 0 (expected.len() == 1).
        let expected: Vec<Vec<Tensor>> =
            vec![inputs.iter().map(|x| oracle.forward_full(&be, x).unwrap()).collect()];
        let mut cl = server.client();
        handles.push(std::thread::spawn(move || {
            // Window 6: submits and responses genuinely interleave.
            let counts = drive_and_verify(&mut cl, &inputs, &expected, |i| i, m, 6)
                .unwrap_or_else(|e| panic!("client {c}: {e:#}"));
            assert_eq!(counts, vec![m as u64], "client {c}: response count per epoch");
        }));
    }
    for h in handles {
        h.join().unwrap_or_else(|e| std::panic::resume_unwind(e));
    }

    // Telemetry acceptance: the submit→respond latency histogram saw
    // every request (it is always on — not gated by LAYERPIPE2_OBS),
    // its quantiles are ordered, and the legacy latency_ms() view agrees
    // with the bucket floors.
    let lat = server.latency_hist();
    assert_eq!(lat.count, (n_clients * m) as u64, "{name}: latency sample count");
    let (p50, p90, p99) = (lat.quantile_ns(0.50), lat.quantile_ns(0.90), lat.quantile_ns(0.99));
    assert!(p50 > 0, "{name}: zero p50 latency");
    assert!(p50 <= p90 && p90 <= p99, "{name}: latency quantiles out of order");
    let (ms50, ms99) = server.latency_ms().expect("latency view empty after traffic");
    assert_eq!((ms50, ms99), (p50 as f64 / 1e6, p99 as f64 / 1e6));

    let stats = server.shutdown().unwrap();
    assert_eq!(stats.submitted, (n_clients * m) as u64, "{name}: submit count");
    assert_eq!(stats.completed, (n_clients * m) as u64, "{name}: response count");
    assert_eq!(stats.dropped, 0, "{name}: dropped responses");
    assert!(stats.batches > 0 && stats.batches <= stats.submitted, "{name}: batch count");
    // Queue-depth gauge: every submit was matched by a respond, so the
    // level is back to zero; every emitted batch had exactly one flush
    // reason.
    assert_eq!(stats.queue_depth, 0, "{name}: queue gauge nonzero after drain");
    assert_eq!(
        stats.flush_full + stats.flush_shrank + stats.flush_force + stats.flush_wait,
        stats.batches,
        "{name}: flush reasons don't partition the batches"
    );
}

#[test]
fn concurrent_clients_match_sequential_oracle_bitwise_dense() {
    stress_one("dense", &dense_spec(), 2);
}

#[test]
fn concurrent_clients_match_sequential_oracle_bitwise_conv() {
    stress_one("conv", &conv_spec(), 3);
}

#[test]
fn hot_reload_under_load_never_tears_a_version() {
    // Four weight versions of the same architecture; the server starts
    // on v0 and hot-reloads v1..v3 while three client threads keep the
    // pipeline full. Every response must match exactly the oracle of
    // the epoch it is tagged with — a torn mix of two versions would
    // match none of them bitwise.
    let spec = dense_spec();
    let versions: Vec<Network> =
        (0..4u64).map(|k| Network::build(&spec, &mut Rng::new(100 + k)).unwrap()).collect();
    let in_dim = versions[0].input_dim();
    let be = HostBackend::new();
    let inputs: Vec<Tensor> =
        (0..10).map(|i| Tensor::randn(&[1 + i % 4, in_dim], 1.0, &mut Rng::new(50 + i as u64))).collect();
    let expected: Vec<Vec<Tensor>> = versions
        .iter()
        .map(|v| {
            let mut o = v.snapshot().unwrap();
            inputs.iter().map(|x| o.forward_full(&be, x).unwrap()).collect()
        })
        .collect();

    let cfg = ServerConfig {
        max_batch: 8,
        max_wait_ticks: 1,
        queue_depth: 16,
        stages: 2,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &versions[0], &cfg).unwrap();
    let m = 48usize;

    std::thread::scope(|s| {
        let inputs = &inputs;
        let expected = &expected;
        for c in 0..3usize {
            let mut cl = server.client();
            s.spawn(move || {
                // Lockstep (window 0) so reloads interleave the traffic
                // as finely as possible; the harness asserts FIFO order,
                // known + non-decreasing epochs, and that every payload
                // is bitwise the tagged epoch's oracle — a torn read
                // across a hot-reload would match no epoch.
                let pick = |i: usize| (c + 5 * i) % inputs.len();
                let counts = drive_and_verify(&mut cl, inputs, expected, pick, m, 0)
                    .unwrap_or_else(|e| panic!("client {c}: {e:#}"));
                assert_eq!(counts.iter().sum::<u64>(), m as u64, "client {c}: response count");
            });
        }
        for v in versions.iter().skip(1) {
            std::thread::sleep(std::time::Duration::from_millis(2));
            server.reload(v).unwrap();
        }
    });

    // Traffic submitted after the last reload must see the final epoch.
    let mut cl = server.client();
    cl.submit(inputs[0].clone()).unwrap();
    let r = cl.recv().unwrap();
    assert_eq!(r.version, 3, "post-reload batch must carry the newest epoch");
    assert_eq!(r.data, expected[3][0]);

    let stats = server.shutdown().unwrap();
    assert_eq!(stats.reloads, 3);
    assert_eq!(stats.epoch, 3);
    assert_eq!(stats.completed, (3 * m + 1) as u64);
    assert_eq!(stats.dropped, 0);
}

#[test]
fn restore_from_disk_roundtrip_serves_identically() {
    // save(net_a) -> reload_from_file must serve bitwise like net_a,
    // after an intermediate reload proved the swap is observable.
    let spec = conv_spec();
    let net_a = Network::build(&spec, &mut Rng::new(7)).unwrap();
    let net_b = Network::build(&spec, &mut Rng::new(8)).unwrap();
    let be = HostBackend::new();
    let x = Tensor::randn(&[3, net_a.input_dim()], 1.0, &mut Rng::new(9));
    let want_a = net_a.snapshot().unwrap().forward_full(&be, &x).unwrap();
    let want_b = net_b.snapshot().unwrap().forward_full(&be, &x).unwrap();
    assert_ne!(want_a, want_b, "versions must be distinguishable");

    let path = std::env::temp_dir().join(format!("lp2_serve_rt_{}.bin", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    checkpoint::save_network(&net_a, &path).unwrap();

    let cfg = ServerConfig {
        max_batch: 4,
        max_wait_ticks: 0,
        queue_depth: 8,
        stages: 2,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &net_a, &cfg).unwrap();
    let mut cl = server.client();

    // Epoch 0: the in-memory original.
    cl.submit(x.clone()).unwrap();
    let r0 = cl.recv().unwrap();
    assert_eq!((r0.version, &r0.data), (0, &want_a));

    // Epoch 1: different weights — observably different responses.
    server.reload(&net_b).unwrap();
    cl.submit(x.clone()).unwrap();
    let r1 = cl.recv().unwrap();
    assert_eq!((r1.version, &r1.data), (1, &want_b));

    // Epoch 2: restored from disk — bitwise back to the original.
    let epoch = server.reload_from_file(&path).unwrap();
    assert_eq!(epoch, 2);
    std::fs::remove_file(&path).ok();
    cl.submit(x.clone()).unwrap();
    let r2 = cl.recv().unwrap();
    assert_eq!(r2.version, 2);
    assert_eq!(
        r2.data, want_a,
        "disk-roundtripped checkpoint must serve bitwise like the network it was saved from"
    );
    server.shutdown().unwrap();
}

#[test]
fn rejected_reload_leaves_serving_unaffected() {
    // A reload whose architecture mismatches must fail fast without
    // bumping the epoch or disturbing in-flight traffic.
    let net = Network::build(&dense_spec(), &mut Rng::new(3)).unwrap();
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait_ticks: 0,
        queue_depth: 8,
        stages: 2,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &net, &cfg).unwrap();
    let conv = Network::build(&conv_spec(), &mut Rng::new(3)).unwrap();
    assert!(server.reload(&conv).is_err(), "cross-architecture reload must be rejected");
    // Traffic still flows on the original epoch afterwards.
    let mut cl = server.client();
    let x = Tensor::randn(&[2, net.input_dim()], 1.0, &mut Rng::new(4));
    cl.submit(x.clone()).unwrap();
    let r = cl.recv().unwrap();
    assert_eq!(r.version, 0);
    let mut oracle = net.snapshot().unwrap();
    assert_eq!(r.data, oracle.forward_full(&HostBackend::new(), &x).unwrap());
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.reloads, 0, "rejected reload must not bump the epoch");
}
