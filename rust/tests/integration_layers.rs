//! Integration: the heterogeneous layer subsystem end to end.
//!
//! The acceptance bar of the layers PR:
//!  - a conv+pool+dense stack and a dense+LIF spiking stack both train
//!    through the multi-threaded `PipelinedTrainer` with cost-balanced
//!    stages, matching the iteration-indexed `Trainer` oracle ≤ 1e-4
//!    for **all five** weight-version strategies (the Fig. 5 sweep on
//!    non-dense workloads);
//!  - stage boundaries come from the per-layer cost reports while the
//!    gradient delays stay `2·S(l)` (downstream stage count only);
//!  - heterogeneous checkpoints roundtrip;
//!  - the CNN actually learns on the image teacher data.
//!
//! Everything runs on the host backend so a clean checkout exercises
//! the full machinery.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::{image_teacher_dataset, teacher_dataset, Splits};
use layerpipe2::layers::{Feature, LayerSpec, Network, NetworkSpec};
use layerpipe2::metrics::RunCurve;
use layerpipe2::model::checkpoint;
use layerpipe2::pipeline::PipelinedTrainer;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::Tensor;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn host() -> Backend {
    Arc::new(HostBackend::new())
}

/// The equivalence workload: conv + pool + flatten + dense + LIF + dense
/// — every layer kind in one stack, 3 cost-balanced stages.
fn hetero_spec() -> NetworkSpec {
    NetworkSpec {
        input: Feature::Image { h: 6, w: 6, c: 1 },
        layers: vec![
            LayerSpec::Conv2d { out_c: 3, k: 3, stride: 1, pad: 1, relu: true },
            LayerSpec::MaxPool2d { k: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 16, relu: false },
            LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            LayerSpec::Dense { units: 4, relu: false },
        ],
        init_scale: 1.0,
    }
}

fn hetero_cfg(epochs: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 8;
    cfg.model.input_dim = 36;
    cfg.model.hidden_dim = 16;
    cfg.model.classes = 4;
    cfg.model.layers = 6;
    cfg.pipeline.stages = 3;
    cfg.epochs = epochs;
    cfg.seed = 13;
    cfg.data = DataConfig {
        train_samples: 96,
        test_samples: 48,
        teacher_hidden: 12,
        label_noise: 0.0,
        seed: 21,
    };
    cfg
}

fn hetero_data(cfg: &ExperimentConfig) -> Splits {
    image_teacher_dataset(6, 6, 1, cfg.model.classes, &cfg.data)
}

/// Train the same (config, spec, strategy) on both engines with the
/// coordinator's seed discipline.
fn run_both(
    cfg: &ExperimentConfig,
    spec: &NetworkSpec,
    data: &Splits,
    kind: StrategyKind,
) -> (RunCurve, RunCurve) {
    let oracle = {
        let mut rng = Rng::new(cfg.seed);
        let mut t = Trainer::with_spec(host(), cfg, spec, kind, &mut rng).expect("oracle init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        t.train(data, &mut batch_rng).expect("oracle train")
    };
    let threaded = {
        let mut rng = Rng::new(cfg.seed);
        let mut ex =
            PipelinedTrainer::with_spec(host(), cfg, spec, kind, &mut rng).expect("executor init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        ex.train(data, &mut batch_rng).expect("executor train")
    };
    (oracle, threaded)
}

fn assert_curves_match(kind: StrategyKind, oracle: &RunCurve, threaded: &RunCurve, tol: f32) {
    assert_eq!(oracle.epochs.len(), threaded.epochs.len(), "{kind:?}: epoch count");
    for (e, (a, b)) in oracle.epochs.iter().zip(&threaded.epochs).enumerate() {
        if a.train_loss.is_nan() || b.train_loss.is_nan() {
            assert!(
                a.train_loss.is_nan() && b.train_loss.is_nan(),
                "{kind:?} epoch {e}: NaN mismatch ({} vs {})",
                a.train_loss,
                b.train_loss
            );
        } else {
            assert!(
                (a.train_loss - b.train_loss).abs() <= tol,
                "{kind:?} epoch {e}: oracle loss {} vs executor {}",
                a.train_loss,
                b.train_loss
            );
        }
        assert!(
            (a.test_accuracy - b.test_accuracy).abs() <= tol,
            "{kind:?} epoch {e}: oracle acc {} vs executor {}",
            a.test_accuracy,
            b.test_accuracy
        );
        assert_eq!(
            a.staleness_bytes, b.staleness_bytes,
            "{kind:?} epoch {e}: staleness accounting diverged"
        );
    }
}

#[test]
fn hetero_executor_matches_oracle_for_all_five_strategies() {
    // The PR's bitwise-equivalence bar: conv + dense + LIF through real
    // threaded stages, every Fig. 5 strategy within 1e-4 of the oracle.
    let cfg = hetero_cfg(3);
    let spec = hetero_spec();
    let data = hetero_data(&cfg);
    for &kind in StrategyKind::all() {
        let (oracle, threaded) = run_both(&cfg, &spec, &data, kind);
        assert_curves_match(kind, &oracle, &threaded, 1e-4);
    }
}

#[test]
fn hetero_partition_is_cost_balanced_with_eq1_delays() {
    let cfg = hetero_cfg(1);
    let spec = hetero_spec();
    let mut rng = Rng::new(cfg.seed);
    let t = Trainer::with_spec(host(), &cfg, &spec, StrategyKind::Stashing, &mut rng).unwrap();
    let p = t.partition();
    assert_eq!(p.stages(), 3);
    // The conv layer dominates compute, so it gets a lean stage while
    // the cheap tail groups together — compare against the balanced
    // optimum recomputed from the cost reports.
    let net = Network::build(&spec, &mut Rng::new(0)).unwrap();
    let costs: Vec<u64> = net.costs(cfg.model.batch).iter().map(|c| c.total_flops()).collect();
    let best = layerpipe2::retiming::StagePartition::balanced(&costs, 3).unwrap();
    assert_eq!(p.stage_of(), best.stage_of());
    assert_eq!(p.max_stage_cost(&costs), best.max_stage_cost(&costs));
    // Delays depend only on downstream stage count (paper Eq. 1),
    // never on costs.
    let delays = t.gradient_delays();
    for (l, &d) in delays.iter().enumerate() {
        assert_eq!(d, 2 * p.downstream_stages(l));
    }
    // Grouped layers share their stage's delay.
    for l in 1..delays.len() {
        if p.stage_of()[l] == p.stage_of()[l - 1] {
            assert_eq!(delays[l], delays[l - 1]);
        }
    }
}

#[test]
fn cnn_learns_on_image_teacher_data() {
    let mut cfg = hetero_cfg(5);
    cfg.data.train_samples = 256;
    cfg.data.test_samples = 96;
    cfg.model.layers = 5;
    // Pure conv+pool+dense classifier (no spiking bottleneck) — the
    // learning bar; the spiking stack's bar is stability + equivalence.
    let spec = NetworkSpec {
        input: Feature::Image { h: 6, w: 6, c: 1 },
        layers: vec![
            LayerSpec::Conv2d { out_c: 4, k: 3, stride: 1, pad: 1, relu: true },
            LayerSpec::MaxPool2d { k: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 24, relu: true },
            LayerSpec::Dense { units: 4, relu: false },
        ],
        init_scale: 1.0,
    };
    let data = hetero_data(&cfg);
    let mut rng = Rng::new(cfg.seed);
    let mut t =
        Trainer::with_spec(host(), &cfg, &spec, StrategyKind::Sequential, &mut rng).unwrap();
    let mut batch_rng = Rng::new(5);
    let curve = t.train(&data, &mut batch_rng).unwrap();
    let chance = 1.0 / cfg.model.classes as f32;
    assert!(
        curve.final_accuracy() > 1.5 * chance,
        "CNN failed to learn: {} (chance {chance})",
        curve.final_accuracy()
    );
    let first = curve.epochs.first().unwrap().train_loss;
    let last = curve.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss {first} → {last}");
}

#[test]
fn snn_trains_with_surrogate_gradients_under_pipeline_delays() {
    // Dense+LIF under real pipeline delays: gradients exist (surrogate),
    // training is stable (finite loss), both engines agree.
    let mut cfg = hetero_cfg(2);
    cfg.model.input_dim = 24;
    cfg.model.hidden_dim = 20;
    cfg.model.layers = 5;
    cfg.pipeline.stages = 3;
    let spec = NetworkSpec {
        input: Feature::Flat(24),
        layers: vec![
            LayerSpec::Dense { units: 20, relu: false },
            LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            LayerSpec::Dense { units: 20, relu: false },
            LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            LayerSpec::Dense { units: 4, relu: false },
        ],
        init_scale: 1.0,
    };
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let (oracle, threaded) = run_both(&cfg, &spec, &data, StrategyKind::PipelineAwareEma);
    assert_curves_match(StrategyKind::PipelineAwareEma, &oracle, &threaded, 1e-4);
    for e in &oracle.epochs {
        assert!(e.train_loss.is_finite(), "SNN loss diverged: {}", e.train_loss);
    }
}

#[test]
fn hetero_network_checkpoint_roundtrips_through_training() {
    // Train a few iterations, checkpoint, perturb, restore, and verify
    // the restored network evaluates identically.
    let cfg = hetero_cfg(1);
    let spec = hetero_spec();
    let data = hetero_data(&cfg);
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::with_spec(host(), &cfg, &spec, StrategyKind::Latest, &mut rng).unwrap();
    let mut batch_rng = Rng::new(5);
    t.train(&data, &mut batch_rng).unwrap();

    let bytes = checkpoint::network_to_bytes(&t.net);
    let mut restored = Network::build(&spec, &mut Rng::new(999)).unwrap();
    checkpoint::network_from_bytes(&mut restored, &bytes).unwrap();
    for (a, b) in t.net.layers.iter().zip(&restored.layers) {
        assert_eq!(a.w, b.w);
        assert_eq!(a.b, b.b);
    }
    let be = HostBackend::new();
    let x = Tensor::randn(&[4, 36], 1.0, &mut Rng::new(3));
    let mut snap = t.net.snapshot().unwrap();
    assert_eq!(
        snap.forward_full(&be, &x).unwrap(),
        restored.forward_full(&be, &x).unwrap()
    );
}

#[test]
fn executor_snapshot_matches_oracle_params_bitwise() {
    // After identical training, the stage-distributed parameters must
    // equal the oracle's exactly (the executor is the oracle, threaded).
    //
    // Snapshot note (kernel-overhaul PR): the deterministic tree
    // reduction in `matmul_tn_into` reassociates the dw summation once
    // the reduced dimension exceeds one chunk (r > 64 — true for this
    // conv's im2col rows), so absolute parameter values differ from the
    // pre-tree sequential kernel and any externally stored curves from
    // before that PR are stale. The bitwise bar is unaffected — oracle
    // and executor share the kernel, and its chunk geometry is a pure
    // function of the shape, so both engines see identical f32 streams
    // for every LAYERPIPE2_WORKERS value. This param-bitwise snapshot is
    // recomputed live on both engines each run (nothing on disk to
    // regenerate), which is exactly why the kernel change rides through
    // it: the two sides move together or the test fails.
    let cfg = hetero_cfg(2);
    let spec = hetero_spec();
    let data = hetero_data(&cfg);
    let kind = StrategyKind::Stashing;
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::with_spec(host(), &cfg, &spec, kind, &mut rng).unwrap();
    let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
    t.train(&data, &mut batch_rng).unwrap();
    let mut rng = Rng::new(cfg.seed);
    let mut ex = PipelinedTrainer::with_spec(host(), &cfg, &spec, kind, &mut rng).unwrap();
    let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
    ex.train(&data, &mut batch_rng).unwrap();
    let net = ex.network().unwrap();
    for (l, (a, b)) in t.net.layers.iter().zip(&net.layers).enumerate() {
        assert_eq!(a.w, b.w, "layer {l} weights diverged");
        assert_eq!(a.b, b.b, "layer {l} biases diverged");
    }
}
