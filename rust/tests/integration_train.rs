//! Integration: the pipelined trainer over a real execution backend.
//!
//! Runs on whatever `backend::from_env` selects — the pure-Rust host
//! backend from a clean checkout (no artifacts, no PJRT), or the PJRT
//! artifact path when present — so `cargo test -q` is green everywhere.
//! Verifies the delayed-gradient semantics end-to-end: the sequential
//! strategy is exact backprop, pipelined strategies carry the Eq. 1
//! delays, stashing stays numerically consistent, and the memory
//! accounting matches O(L·S) vs O(L).

use layerpipe2::backend::{self, Backend, Exec, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::coordinator::Coordinator;
use layerpipe2::data::teacher_dataset;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn backend() -> Backend {
    backend::from_env("artifacts").expect("auto backend selection never fails")
}

fn quick_cfg(epochs: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.epochs = epochs;
    cfg.data = DataConfig {
        train_samples: 512,
        test_samples: 256,
        teacher_hidden: 48,
        label_noise: 0.0,
        seed: 99,
    };
    cfg
}

#[test]
fn delays_match_eq1_for_trainer() {
    let cfg = quick_cfg(1);
    let mut rng = Rng::new(1);
    let t = Trainer::new(backend(), &cfg, StrategyKind::Stashing, &mut rng).unwrap();
    assert_eq!(t.gradient_delays(), vec![14, 12, 10, 8, 6, 4, 2, 0]);
    let seq = Trainer::new(backend(), &cfg, StrategyKind::Sequential, &mut rng).unwrap();
    assert_eq!(seq.gradient_delays(), vec![0; 8]);
}

#[test]
fn sequential_training_learns() {
    let cfg = quick_cfg(3);
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::new(backend(), &cfg, StrategyKind::Sequential, &mut rng).unwrap();
    let mut batch_rng = Rng::new(5);
    let curve = t.train(&data, &mut batch_rng).unwrap();
    let random_acc = 1.0 / cfg.model.classes as f32;
    assert!(
        curve.final_accuracy() > 2.0 * random_acc,
        "no learning: {}",
        curve.final_accuracy()
    );
    // Loss decreases across epochs.
    let first = curve.epochs.first().unwrap().train_loss;
    let last = curve.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss {first} → {last}");
}

#[test]
fn stashing_converges_under_full_delay() {
    let cfg = quick_cfg(3);
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::new(backend(), &cfg, StrategyKind::Stashing, &mut rng).unwrap();
    let mut batch_rng = Rng::new(5);
    let curve = t.train(&data, &mut batch_rng).unwrap();
    assert!(
        curve.final_accuracy() > 1.5 / cfg.model.classes as f32 * 2.0,
        "delayed-but-consistent gradients must converge: {}",
        curve.final_accuracy()
    );
    // Stashing must hold O(Σ d_l) weight versions.
    assert!(t.staleness_bytes() > 0);
}

#[test]
fn pipeline_ema_memory_is_o_l_not_o_ls() {
    let cfg = quick_cfg(2);
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let run = |kind| {
        let mut rng = Rng::new(cfg.seed);
        let mut t = Trainer::new(backend(), &cfg, kind, &mut rng).unwrap();
        let mut batch_rng = Rng::new(5);
        t.train(&data, &mut batch_rng).unwrap();
        t.staleness_bytes()
    };
    let stash = run(StrategyKind::Stashing);
    let ema = run(StrategyKind::PipelineAwareEma);
    // 8 layers, delays 14..0: stash ≈ Σ(d_l+1)·|W| = 64 versions vs 8
    // EMA accumulators → ≥ 6× reduction even counting the mixed shapes.
    assert!(
        stash > 5 * ema,
        "expected O(LS) vs O(L): stash {stash} B, ema {ema} B"
    );
}

#[test]
fn coordinator_sweep_is_deterministic() {
    // Same config ⇒ bit-identical curves (init, batch order, and backend
    // compute are all deterministic), and the sweep covers every
    // requested strategy under the same data.
    let mut cfg = quick_cfg(1);
    cfg.strategies = vec![StrategyKind::Sequential, StrategyKind::Latest];
    let coord = Coordinator::new(cfg).unwrap();
    let a = coord.sweep().unwrap();
    let b = coord.sweep().unwrap();
    assert_eq!(a.curves.len(), 2);
    for (ca, cb) in a.curves.iter().zip(&b.curves) {
        assert_eq!(ca.strategy, cb.strategy);
        for (ea, eb) in ca.epochs.iter().zip(&cb.epochs) {
            // Everything but wall-clock must be bit-identical.
            assert_eq!(ea.train_loss, eb.train_loss, "loss not deterministic");
            assert_eq!(ea.test_accuracy, eb.test_accuracy, "accuracy not deterministic");
            assert_eq!(ea.staleness_bytes, eb.staleness_bytes);
            assert_eq!(ea.activation_bytes, eb.activation_bytes);
        }
    }
}

#[test]
fn model_shape_checks_follow_the_backend() {
    // The host backend serves any validated shape; the PJRT backend is
    // locked to its artifact preset and must fail fast with a readable
    // error rather than crash inside XLA.
    let mut cfg = quick_cfg(1);
    cfg.model.hidden_dim = 128;
    let mut rng = Rng::new(0);
    let host: Backend = Arc::new(HostBackend::new());
    Trainer::new(host, &cfg, StrategyKind::Sequential, &mut rng)
        .expect("host backend accepts any shape");
    let auto = backend();
    if auto.name() == "pjrt" {
        let err = Trainer::new(auto, &cfg, StrategyKind::Sequential, &mut rng);
        let msg = format!("{:#}", err.err().expect("preset mismatch must fail"));
        assert!(msg.contains("preset"), "got: {msg}");
    }
}

#[test]
fn grouped_pipeline_trains_with_shared_delays() {
    // 4 stages over 8 layers: two-layer groups share their stage's
    // delay 2·(3−stage) ⇒ [6,6,4,4,2,2,0,0] (the Fig. 4 structure).
    let mut cfg = quick_cfg(2);
    cfg.pipeline.stages = 4;
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::new(backend(), &cfg, StrategyKind::PipelineAwareEma, &mut rng).unwrap();
    assert_eq!(t.gradient_delays(), vec![6, 6, 4, 4, 2, 2, 0, 0]);
    let mut batch_rng = Rng::new(5);
    let curve = t.train(&data, &mut batch_rng).unwrap();
    assert!(curve.final_accuracy() > 1.0 / cfg.model.classes as f32);
}
