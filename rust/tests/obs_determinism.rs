//! Observability must not perturb numerics (DESIGN.md §12): training the
//! threaded pipeline with the span gate off vs on must produce
//! bitwise-identical final weights — obs reads clocks, it never branches
//! on them. With the gate on, the per-stage span accounting must
//! actually cover the stage wall time, and an armed Chrome-trace window
//! must round-trip through `util::json` with monotonic per-thread
//! timestamps.
//!
//! The obs gate is process-global, so this file holds a single `#[test]`
//! that toggles it sequentially.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::teacher_dataset;
use layerpipe2::layers::Network;
use layerpipe2::obs;
use layerpipe2::pipeline::PipelinedTrainer;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::util::json::Json;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 8;
    cfg.model.input_dim = 12;
    cfg.model.hidden_dim = 10;
    cfg.model.classes = 4;
    cfg.model.layers = 4;
    cfg.pipeline.stages = 4;
    cfg.epochs = 2;
    cfg.data = DataConfig {
        train_samples: 64,
        test_samples: 32,
        teacher_hidden: 8,
        label_noise: 0.0,
        seed: 3,
    };
    cfg
}

/// Train the threaded executor once and return the final network plus
/// the telemetry window the run accumulated (empty when the gate is
/// off) and the trainer itself (for `bubble_report`).
fn train_once(cfg: &ExperimentConfig) -> (Network, obs::TelemetrySnapshot, PipelinedTrainer) {
    let backend: Backend = Arc::new(HostBackend::new());
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let before = obs::TelemetrySnapshot::capture();
    let mut rng = Rng::new(1);
    let mut trainer =
        PipelinedTrainer::new(backend, cfg, StrategyKind::PipelineAwareEma, &mut rng).unwrap();
    let mut batch_rng = Rng::new(5);
    trainer.train(&data, &mut batch_rng).unwrap();
    let window = obs::TelemetrySnapshot::capture().diff(&before);
    (trainer.network().unwrap(), window, trainer)
}

#[test]
fn obs_gate_is_bit_invisible_and_spans_cover_wall_time() {
    let cfg = tiny_cfg();

    // ---- gate off: no stage spans recorded -----------------------------
    obs::set_enabled(false);
    let (net_off, window_off, _) = train_once(&cfg);
    assert!(
        window_off.span("stage0", "pipeline/stage").map_or(true, |s| s.total_ns == 0),
        "span timing leaked through a disabled gate"
    );

    // ---- gate on, trace armed ------------------------------------------
    obs::set_enabled(true);
    obs::trace_begin();
    let (net_on, window_on, trainer) = train_once(&cfg);
    let trace = obs::trace_end_to_json();

    // Determinism: final weights bitwise identical across gate states.
    assert_eq!(net_off.layers.len(), net_on.layers.len());
    for (l, (a, b)) in net_off.layers.iter().zip(net_on.layers.iter()).enumerate() {
        assert_eq!(a.w.shape(), b.w.shape(), "layer {l} weight shape changed");
        assert!(
            a.w.data().iter().zip(b.w.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "layer {l} weights differ bitwise with obs on vs off"
        );
        assert!(
            a.b.data().iter().zip(b.b.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
            "layer {l} biases differ bitwise with obs on vs off"
        );
    }

    // Bubble accounting: every stage has a wall span, the
    // compute/recv/send/other breakdown sums to it (within the 5%
    // acceptance bar; exact by construction today), and the shares are
    // proper distributions.
    let report = trainer.bubble_report(&window_on);
    assert_eq!(report.len(), cfg.pipeline.stages);
    let (mut predicted, mut measured) = (0.0f64, 0.0f64);
    for b in &report {
        assert!(b.wall_ns > 0, "stage {} recorded no wall span with obs on", b.stage);
        assert!(b.compute_ns > 0, "stage {} recorded no compute spans", b.stage);
        let parts = b.compute_ns + b.recv_ns + b.send_ns + b.other_ns;
        let rel = (parts as f64 - b.wall_ns as f64).abs() / b.wall_ns as f64;
        assert!(
            rel <= 0.05,
            "stage {}: breakdown {parts}ns vs wall {}ns ({:.1}% apart)",
            b.stage,
            b.wall_ns,
            rel * 100.0
        );
        assert!(
            (0.0..=1.0).contains(&b.bubble_fraction),
            "stage {}: bubble fraction {} outside [0,1]",
            b.stage,
            b.bubble_fraction
        );
        predicted += b.predicted_share;
        measured += b.measured_share;
    }
    assert!((predicted - 1.0).abs() < 1e-9, "predicted shares sum to {predicted}");
    assert!((measured - 1.0).abs() < 1e-6, "measured shares sum to {measured}");

    // The JSON export carries the span rows the report was built from.
    let snap_json = window_on.to_json();
    assert!(snap_json.get("spans").is_some(), "telemetry JSON lost its spans section");

    // Chrome-trace round trip: serialized dump parses back through
    // util::json, contains the stage spans, and per-thread timestamps
    // are monotonically nondecreasing.
    let parsed = Json::parse(&trace.to_string()).expect("trace dump must be valid JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("trace dump lacks traceEvents");
    let mut saw_stage_span = false;
    let mut last_ts: std::collections::BTreeMap<i64, f64> = std::collections::BTreeMap::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("event lacks ph");
        if ph != "X" {
            continue;
        }
        let name = ev.get("name").and_then(|n| n.as_str()).expect("event lacks name");
        saw_stage_span |= name == "pipeline/stage";
        let tid = ev.get("tid").and_then(|t| t.as_f64()).expect("event lacks tid") as i64;
        let ts = ev.get("ts").and_then(|t| t.as_f64()).expect("event lacks ts");
        assert!(
            ev.get("dur").and_then(|d| d.as_f64()).expect("event lacks dur") >= 0.0,
            "negative span duration in trace"
        );
        let prev = last_ts.entry(tid).or_insert(f64::NEG_INFINITY);
        assert!(
            ts >= *prev,
            "trace timestamps regressed on tid {tid}: {ts} after {prev}"
        );
        *prev = ts;
    }
    assert!(saw_stage_span, "trace dump lost the pipeline/stage spans");
}
