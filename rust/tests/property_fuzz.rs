//! Property/fuzz tests over the substrates: random inputs must never
//! panic the parsers, and the algebraic invariants must hold for
//! arbitrary generated instances.

use layerpipe2::config::toml::TomlDoc;
use layerpipe2::ema::{ExactWindow, GradientAverager, PipelineAwareEma};
use layerpipe2::graph::Dfg;
use layerpipe2::layers::LayerCost;
use layerpipe2::replica::tree_reduce_into_with_threads;
use layerpipe2::retiming::{closed_form_lags, insert_pipeline_delays, Retiming, StagePartition};
use layerpipe2::schedule::{choose_stages, AdaptiveLimits, CostModel};
use layerpipe2::serving::{AimdBatchControl, Coalescer, Request, TokenBucket};
use layerpipe2::tensor::Tensor;
use layerpipe2::testing::property;
use layerpipe2::util::json::Json;
use layerpipe2::util::Rng;

fn random_ascii(rng: &mut Rng, max_len: usize) -> String {
    let len = rng.index(max_len + 1);
    (0..len)
        .map(|_| {
            let c = 32 + rng.index(95) as u8; // printable ASCII
            c as char
        })
        .collect()
}

#[test]
fn json_parser_never_panics_on_garbage() {
    property(300, |rng, _case| {
        let s = random_ascii(rng, 64);
        let _ = Json::parse(&s); // must return Ok or Err, never panic
    });
}

#[test]
fn json_roundtrip_on_generated_values() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.index(4) } else { rng.index(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.index(2_000_001) as f64) - 1_000_000.0),
            3 => Json::Str(random_ascii(rng, 12)),
            4 => Json::Arr((0..rng.index(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..rng.index(4) {
                    m.insert(random_ascii(rng, 8), gen(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    property(200, |rng, case| {
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: reparse failed: {e} on {text}"));
        assert_eq!(back, v, "case {case}");
    });
}

#[test]
fn toml_parser_never_panics_on_garbage() {
    property(300, |rng, _case| {
        let lines = rng.index(6);
        let mut s = String::new();
        for _ in 0..lines {
            s.push_str(&random_ascii(rng, 40));
            s.push('\n');
        }
        let _ = TomlDoc::parse(&s);
    });
}

#[test]
fn retiming_legality_iff_apply_succeeds() {
    // For random graphs and random lags: apply() succeeds exactly when
    // every retimed edge is non-negative, and cycle delays are invariant.
    property(100, |rng, _case| {
        let layers = 2 + rng.index(6);
        let stage_of: Vec<usize> = {
            let mut v = vec![0usize];
            for _ in 1..layers {
                let next = v.last().unwrap() + usize::from(rng.chance(0.5));
                v.push(next);
            }
            v
        };
        let mut g = Dfg::backprop(layers, &stage_of);
        insert_pipeline_delays(&mut g);
        // Random lags in [-2, 2].
        let mut r = Retiming::identity(&g);
        for lag in r.lags.iter_mut() {
            *lag = rng.index(5) as i64 - 2;
        }
        let manual_legal = g.edges.iter().all(|e| {
            e.delay + r.lags[e.to] - r.lags[e.from] >= 0
        });
        match r.apply(&g) {
            Ok(rg) => {
                assert!(manual_legal, "apply succeeded but edges negative");
                // Cycle invariance through the weight self-loops.
                for (i, n) in g.nodes.iter().enumerate() {
                    if matches!(n.kind, layerpipe2::graph::NodeKind::Weight(_)) {
                        assert_eq!(g.cycle_delay(&[i]), rg.cycle_delay(&[i]));
                    }
                }
            }
            Err(_) => assert!(!manual_legal, "apply failed on a legal retiming"),
        }
    });
}

#[test]
fn closed_form_retiming_is_always_legal() {
    property(100, |rng, _case| {
        let layers = 2 + rng.index(10);
        let stages = 1 + rng.index(layers);
        let p = StagePartition::even(layers, stages).unwrap();
        let mut g = Dfg::backprop(layers, p.stage_of());
        insert_pipeline_delays(&mut g);
        closed_form_lags(&g)
            .apply(&g)
            .expect("closed-form retiming must be legal for every partition");
    });
}

#[test]
fn ema_tracks_exact_window_within_bound() {
    // On bounded-drift update streams the O(1) pipeline-aware EMA stays
    // within a modest factor of the exact sliding-window mean.
    property(60, |rng, case| {
        let d = 2 + rng.index(16);
        let mut exact = ExactWindow::new(d);
        let mut ema = PipelineAwareEma::new(d);
        let mut level = rng.uniform(-1.0, 1.0) as f32;
        for t in 0..300 {
            level += (rng.gauss() as f32) * 0.02; // slow drift
            let u = Tensor::from_vec(&[1], vec![level + (rng.gauss() as f32) * 0.01]);
            exact.push(&u);
            ema.push(&u);
            if t > 4 * d {
                let e = exact.mean().unwrap().data()[0];
                let a = ema.mean().unwrap().data()[0];
                assert!(
                    (e - a).abs() < 0.2,
                    "case {case} d={d} t={t}: exact {e} vs ema {a}"
                );
            }
        }
    });
}

#[test]
fn adaptive_choice_is_always_feasible_and_best() {
    property(80, |rng, case| {
        let layers = 1 + rng.index(12);
        let mut cost = CostModel::uniform(layers);
        for l in 0..layers {
            cost.fwd[l] = 0.5 + rng.f64() * 4.0;
            cost.bwd[l] = 2.0 * cost.fwd[l];
        }
        cost.boundary_bytes = rng.index(1000);
        let limits = AdaptiveLimits {
            max_delay: rng.index(2 * layers + 1),
            max_comm_bytes: if rng.chance(0.5) { 0 } else { rng.index(8000) },
        };
        let c = choose_stages(layers, &cost, &limits);
        assert!(c.max_delay <= limits.max_delay || c.stages == 1, "case {case}");
        // No feasible candidate beats the chosen speedup.
        for &(k, s, feasible) in &c.candidates {
            if feasible {
                assert!(
                    s <= c.speedup + 1e-9,
                    "case {case}: candidate {k} ({s}) beats chosen ({})",
                    c.speedup
                );
            }
        }
    });
}

#[test]
fn adaptive_choice_matches_brute_force_on_hetero_stacks() {
    // The conv-aware schedule model: on random conv+dense stacks, the
    // adaptive choice must (a) evaluate every candidate K on the same
    // cost-balanced boundaries `StagePartition::balanced` derives from
    // the LayerCost totals — with brute-force min-max optimality per K —
    // and (b) pick the feasible K with the best modeled speedup.
    property(50, |rng, case| {
        let layers = 2 + rng.index(7);
        let costs: Vec<LayerCost> = (0..layers)
            // Layer 0 is always conv-like so total cost is nonzero (an
            // all-free stack would make every speedup 0/0).
            .map(|l| match if l == 0 { 0 } else { rng.index(4) } {
                // conv-like: heavy, backward ≈ 2× forward, big activations
                0 => {
                    let f = 1_000 * (1 + rng.index(50)) as u64;
                    LayerCost {
                        fwd_flops: f,
                        bwd_flops: 2 * f,
                        act_bytes: 4_096 + rng.index(8_192) as u64,
                        param_bytes: 512,
                    }
                }
                // dense-like: moderate
                1 | 2 => {
                    let f = 10 * (1 + rng.index(200)) as u64;
                    LayerCost {
                        fwd_flops: f,
                        bwd_flops: 2 * f,
                        act_bytes: 256 + rng.index(1_024) as u64,
                        param_bytes: 256,
                    }
                }
                // flatten/pool-like: free or nearly free
                _ => LayerCost {
                    fwd_flops: rng.index(3) as u64,
                    bwd_flops: rng.index(3) as u64,
                    act_bytes: 128,
                    param_bytes: 0,
                },
            })
            .collect();
        let cm = CostModel::from_layer_costs(&costs);
        let totals: Vec<u64> = costs.iter().map(LayerCost::total_flops).collect();
        let c = choose_stages(layers, &cm, &AdaptiveLimits::default());
        // (a) chosen partition ≡ balanced on the same totals, and that
        // partition is min-max optimal (brute force over boundary masks).
        let want = StagePartition::balanced(&totals, c.stages).unwrap();
        assert_eq!(c.partition.stage_of(), want.stage_of(), "case {case}");
        let got = c.partition.max_stage_cost(&totals);
        let slots = layers - 1;
        let mut best = u64::MAX;
        for mask in 0u32..(1 << slots) {
            if mask.count_ones() as usize != c.stages - 1 {
                continue;
            }
            let (mut mx, mut cur) = (0u64, totals[0]);
            for l in 1..layers {
                if mask & (1 << (l - 1)) != 0 {
                    mx = mx.max(cur);
                    cur = 0;
                }
                cur += totals[l];
            }
            best = best.min(mx.max(cur));
        }
        assert_eq!(got, best, "case {case}: partition not min-max optimal for K={}", c.stages);
        // (b) no candidate K beats the chosen speedup.
        assert_eq!(c.candidates.len(), layers, "case {case}");
        for &(k, s, feasible) in &c.candidates {
            assert!(feasible, "case {case}: unconstrained K={k} must be feasible");
            assert!(
                s <= c.speedup + 1e-9,
                "case {case}: candidate K={k} ({s}) beats chosen ({})",
                c.speedup
            );
        }
    });
}

#[test]
fn schedule_and_partition_agree_for_all_shapes() {
    use layerpipe2::retiming::delay_formula;
    use layerpipe2::schedule::Schedule;
    property(60, |rng, _case| {
        let layers = 2 + rng.index(6);
        let stages = 1 + rng.index(layers);
        let p = StagePartition::even(layers, stages).unwrap();
        let s = Schedule::build(&p, (4 * stages).max(16) as u64);
        let per_stage = s.observed_staleness();
        let formula = delay_formula(p.stage_of());
        for l in 0..layers {
            assert_eq!(per_stage[p.stage_of()[l]], formula[l]);
        }
    });
}

#[test]
fn delay_depends_only_on_downstream_stage_count() {
    // Paper §retiming: `d_l = 2·S(l)` with `S(l)` the number of stages
    // *after* layer l's stage — nothing else. For random heterogeneous
    // cost vectors (conv-heavy, zero-cost flatten layers, spiking-cheap
    // tails), the cost-balanced partition moves the boundaries, but the
    // delay of every layer must still be a pure function of its
    // downstream stage count; grouped layers share one assignment.
    property(120, |rng, case| {
        let layers = 2 + rng.index(12);
        let stages = 1 + rng.index(layers);
        // Heterogeneous cost profile: orders of magnitude apart, with
        // occasional zero-cost (flatten-like) layers.
        let costs: Vec<u64> = (0..layers)
            .map(|_| {
                if rng.chance(0.2) {
                    0
                } else {
                    let scale = 10u64.pow(rng.index(4) as u32);
                    scale * (1 + rng.index(9) as u64)
                }
            })
            .collect();
        let p = StagePartition::balanced(&costs, stages)
            .unwrap_or_else(|e| panic!("case {case}: balanced failed for {costs:?}: {e}"));
        let delays = p.gradient_delays();
        for l in 0..layers {
            // Pure function of downstream stage count…
            assert_eq!(
                delays[l],
                2 * (stages - 1 - p.stage_of()[l]),
                "case {case}: layer {l} of {costs:?}"
            );
        }
        // …so two layers share a delay iff they share a stage (grouped
        // layers get one assignment), and the assignment is independent
        // of the cost vector given the stage map.
        for l in 1..layers {
            if p.stage_of()[l] == p.stage_of()[l - 1] {
                assert_eq!(delays[l], delays[l - 1], "case {case}: grouped layers split");
            } else {
                assert!(delays[l] < delays[l - 1], "case {case}: delays must strictly drop");
            }
        }
        // Cross-check: any other cost vector inducing the same stage map
        // yields identical delays (delays never read costs).
        let same_map = StagePartition::from_stage_of(p.stage_of().to_vec()).unwrap();
        assert_eq!(same_map.gradient_delays(), delays, "case {case}");
    });
}

#[test]
fn balanced_partition_is_optimal_and_contiguous() {
    // The cost-balancing objective itself: for random instances the
    // greedy+binary-search result must match the brute-force min-max
    // over all contiguous partitions (feasible because sizes stay tiny).
    property(60, |rng, case| {
        let layers = 2 + rng.index(7);
        let stages = 1 + rng.index(layers);
        let costs: Vec<u64> = (0..layers).map(|_| rng.index(100) as u64).collect();
        let p = StagePartition::balanced(&costs, stages).unwrap();
        assert_eq!(p.layers(), layers);
        assert_eq!(p.stages(), stages);
        // Contiguity + every stage nonempty is enforced by construction;
        // re-validate through the public constructor.
        StagePartition::from_stage_of(p.stage_of().to_vec())
            .unwrap_or_else(|e| panic!("case {case}: illegal stage map: {e}"));
        // Brute-force optimum via bitmask over boundary placements.
        let got = p.max_stage_cost(&costs);
        let mut best = u64::MAX;
        let slots = layers - 1;
        for mask in 0u32..(1 << slots) {
            if mask.count_ones() as usize != stages - 1 {
                continue;
            }
            let (mut mx, mut cur) = (0u64, costs[0]);
            for l in 1..layers {
                if mask & (1 << (l - 1)) != 0 {
                    mx = mx.max(cur);
                    cur = 0;
                }
                cur += costs[l];
            }
            best = best.min(mx.max(cur));
        }
        assert_eq!(got, best, "case {case}: {costs:?} into {stages}");
    });
}

#[test]
fn serving_coalescer_never_drops_duplicates_reorders_or_overfills() {
    // The serving batcher's pure core, under random request sizes,
    // arrival orders, tick interleavings and (max_batch, max_wait_ticks,
    // shrink_under) configs: the concatenation of all emitted batches
    // must be exactly the arrival sequence (no drop, no duplicate, no
    // reorder — global FIFO implies per-client FIFO), every batch must
    // respect the row cap, and a non-forced emission must be justified
    // (full batch, spent wait budget, or a queue-emptying batch at or
    // under the low-occupancy shrink threshold).
    property(150, |rng, case| {
        let max_batch = 1 + rng.index(8);
        let max_wait = rng.index(5) as u64;
        // shrink_under = 0 (the default) in a third of the cases keeps
        // the legacy behavior under the same harness.
        let shrink_under = if rng.chance(0.33) { 0 } else { rng.index(max_batch + 1) };
        let mut co = Coalescer::with_shrink(max_batch, max_wait, shrink_under);
        let mut expect: Vec<(u32, u64, usize)> = Vec::new();
        let mut got: Vec<(u32, u64, usize)> = Vec::new();
        let mut seqs = [0u64; 4];
        let mut ticks_since_take = 0u64;
        let events = rng.index(60);
        let drain = |co: &mut Coalescer,
                         got: &mut Vec<(u32, u64, usize)>,
                         force: bool,
                         idle: &mut u64| {
            while let Some(batch) = co.take_ready(force) {
                assert!(!batch.is_empty(), "case {case}: empty batch emitted");
                let rows: usize = batch.iter().map(Request::rows).sum();
                assert!(
                    rows <= max_batch,
                    "case {case}: batch of {rows} rows exceeds cap {max_batch}"
                );
                if !force {
                    // Justified: full (cap hit or next request pending
                    // didn't fit), the wait budget was spent, or the
                    // batch emptied the queue at low occupancy (shrink).
                    let full = rows == max_batch || co.pending_rows() > 0;
                    let shrank = co.pending_rows() == 0 && rows <= shrink_under;
                    assert!(
                        full || shrank || *idle >= max_wait,
                        "case {case}: partial batch ({rows}/{max_batch} rows, \
                         shrink_under {shrink_under}) emitted after only {idle} \
                         idle ticks (budget {max_wait})"
                    );
                }
                *idle = 0;
                got.extend(batch.iter().map(|r| (r.client, r.seq, r.rows())));
            }
        };
        for _ in 0..events {
            if rng.chance(0.35) {
                co.tick();
                // Mirror the coalescer's own rule exactly — ticks count
                // only while requests are pending — so the shadow idle
                // counter equals its internal wait budget and the
                // justification assertion below stays tight.
                if co.pending_rows() > 0 {
                    ticks_since_take += 1;
                }
            } else {
                let client = rng.index(4) as u32;
                let rows = 1 + rng.index(max_batch);
                let seq = seqs[client as usize];
                seqs[client as usize] += 1;
                expect.push((client, seq, rows));
                co.push(Request {
                    client,
                    seq,
                    data: Tensor::zeros(&[rows, 1]),
                    born: std::time::Instant::now(),
                    born_tick: 0,
                    deadline_ticks: 0,
                });
            }
            drain(&mut co, &mut got, false, &mut ticks_since_take);
        }
        drain(&mut co, &mut got, true, &mut ticks_since_take);
        assert!(co.take_ready(true).is_none(), "case {case}: drain left requests behind");
        assert_eq!(
            got, expect,
            "case {case}: emitted stream is not the arrival stream (drop/dup/reorder)"
        );
    });
}

#[test]
fn serving_token_bucket_admitted_cost_is_rate_bounded() {
    // Admission control's pure core: over random (capacity, refill)
    // configs and random tick sequences — monotonic, repeated, and
    // stale ticks alike — the total admitted cost can never exceed
    // `capacity + refill · highest-tick-seen` (the bucket starts full
    // at tick 0), and the bucket never holds more than `capacity`
    // tokens. Stale ticks must refill nothing.
    property(200, |rng, case| {
        let capacity = 1 + rng.index(16) as u64;
        let refill = rng.index(4) as u64;
        let mut tb = TokenBucket::new(capacity, refill);
        let mut now = 0u64;
        let mut hi = 0u64;
        let mut admitted = 0u64;
        for _ in 0..rng.index(80) {
            match rng.index(4) {
                0 => now += rng.index(5) as u64,
                1 => {} // repeated tick
                2 => now = now.saturating_sub(rng.index(3) as u64), // stale tick
                _ => now += 1,
            }
            hi = hi.max(now);
            let cost = 1 + rng.index(6) as u64;
            if tb.admit(now, cost) {
                admitted += cost;
            }
            assert!(
                tb.tokens() <= capacity,
                "case {case}: bucket overfilled ({} > {capacity})",
                tb.tokens()
            );
            assert!(
                admitted <= capacity + refill * hi,
                "case {case}: admitted {admitted} tokens exceeds burst {capacity} \
                 + {refill}/tick over {hi} ticks"
            );
        }
    });
}

#[test]
fn serving_deadline_shed_partitions_the_arrival_stream() {
    // Deadline shedding on the tick clock: under random interleavings
    // of push / tick / shed_expired / take_ready, every pushed request
    // leaves the coalescer exactly once — as an emitted batch member or
    // as shed — each stream individually preserving arrival order, and
    // a request is shed only when genuinely expired on the tick clock
    // (`now − born_tick ≥ deadline_ticks`; deadline 0 is never shed).
    property(150, |rng, case| {
        let max_batch = 1 + rng.index(6);
        let max_wait = rng.index(4) as u64;
        let mut co = Coalescer::new(max_batch, max_wait);
        let mut emitted: Vec<u64> = Vec::new();
        let mut shed: Vec<u64> = Vec::new();
        let mut scratch = Vec::new();
        let mut seq = 0u64;
        for _ in 0..rng.index(80) {
            match rng.index(4) {
                0 => co.tick(),
                1 => {
                    let rows = 1 + rng.index(max_batch);
                    let deadline = rng.index(6) as u64; // 0 = never expires
                    co.push(Request {
                        client: 0,
                        seq,
                        data: Tensor::zeros(&[rows, 1]),
                        born: std::time::Instant::now(),
                        born_tick: co.now(),
                        deadline_ticks: deadline,
                    });
                    seq += 1;
                }
                2 => {
                    scratch.clear();
                    co.shed_expired(&mut scratch);
                    for r in &scratch {
                        let age = co.now() - r.born_tick;
                        assert!(
                            r.deadline_ticks > 0 && age >= r.deadline_ticks,
                            "case {case}: seq {} shed at age {age} ticks with \
                             deadline {} — not expired",
                            r.seq,
                            r.deadline_ticks
                        );
                        shed.push(r.seq);
                    }
                }
                _ => {
                    if let Some(batch) = co.take_ready(rng.chance(0.2)) {
                        emitted.extend(batch.iter().map(|r| r.seq));
                    }
                }
            }
        }
        scratch.clear();
        co.drain_all(&mut scratch);
        emitted.extend(scratch.iter().map(|r| r.seq));
        let mut all: Vec<u64> = emitted.iter().chain(&shed).copied().collect();
        all.sort_unstable();
        let want: Vec<u64> = (0..seq).collect();
        assert_eq!(all, want, "case {case}: requests lost or duplicated across emit/shed");
        assert!(
            emitted.windows(2).all(|w| w[0] < w[1]),
            "case {case}: emitted stream reordered"
        );
        assert!(
            shed.windows(2).all(|w| w[0] < w[1]),
            "case {case}: shed stream reordered"
        );
    });
}

#[test]
fn serving_aimd_limits_never_leave_the_clamps() {
    // The AIMD controller under arbitrary p99 observations: whatever the
    // pressure sequence, the returned (batch, wait) limits stay inside
    // the configured [min, max] clamps, and `limits()` always agrees
    // with the last `observe()` return.
    property(200, |rng, case| {
        let max_batch = 1 + rng.index(32);
        let min_batch = 1 + rng.index(max_batch);
        let max_wait = rng.index(16) as u64;
        let min_wait = rng.index(max_wait as usize + 1) as u64;
        let target = 1 + rng.index(5_000_000) as u64;
        let mut ctl = AimdBatchControl::new(min_batch, max_batch, min_wait, max_wait, target);
        for _ in 0..rng.index(100) {
            let p99 = rng.index(10_000_000) as u64;
            let (b, w) = ctl.observe(p99);
            assert!(
                (min_batch..=max_batch).contains(&b),
                "case {case}: batch {b} outside [{min_batch}, {max_batch}]"
            );
            assert!(
                (min_wait..=max_wait).contains(&w),
                "case {case}: wait {w} outside [{min_wait}, {max_wait}]"
            );
            assert_eq!((b, w), ctl.limits(), "case {case}: limits() disagrees with observe()");
        }
    });
}

#[test]
fn replica_tree_reduce_is_bitwise_stable_for_all_shapes_and_threads() {
    // The replica ring's deterministic all-reduce: for random tensor
    // shapes, part counts (1..=8 shards) and worker counts (1..=8), the
    // reduction must (a) equal a scalar per-element gap-doubling
    // reference **bitwise** — the combine order is a pure function of
    // the slot index, never of chunking — and (b) be bitwise identical
    // across every thread count, which is what makes N-replica training
    // reproduce the single-replica oracle bit for bit.
    property(60, |rng, case| {
        let parts_n = 1 + rng.index(8);
        let len = 1 + rng.index(3000);
        let inv = if rng.chance(0.5) { 1.0 } else { 1.0 / parts_n as f32 };
        let parts: Vec<Tensor> =
            (0..parts_n).map(|_| Tensor::randn(&[len], 1.0, rng)).collect();

        // Scalar reference: per element, fold the parts in fixed
        // gap-doubling order ((p0+p1)+(p2+p3))+…
        let reference: Vec<f32> = (0..len)
            .map(|i| {
                let mut acc: Vec<f32> = parts.iter().map(|p| p.data()[i]).collect();
                let mut gap = 1;
                while gap < acc.len() {
                    let mut k = 0;
                    while k + gap < acc.len() {
                        acc[k] += acc[k + gap];
                        k += 2 * gap;
                    }
                    gap *= 2;
                }
                if inv == 1.0 { acc[0] } else { acc[0] * inv }
            })
            .collect();

        let mut first: Option<Vec<u32>> = None;
        for threads in 1..=8 {
            let mut out = Tensor::empty();
            tree_reduce_into_with_threads(&parts, &mut out, inv, threads);
            assert_eq!(out.shape(), &[len], "case {case}: bad output shape");
            let bits: Vec<u32> = out.data().iter().map(|v| v.to_bits()).collect();
            for (i, (&got, &want)) in out.data().iter().zip(&reference).enumerate() {
                assert!(
                    got.to_bits() == want.to_bits(),
                    "case {case}: element {i} differs from scalar reference \
                     ({got} vs {want}, {parts_n} parts, {threads} threads)"
                );
            }
            match &first {
                None => first = Some(bits),
                Some(f) => assert_eq!(
                    &bits, f,
                    "case {case}: thread count {threads} changed the bits"
                ),
            }
        }
    });
}

/// The value a bf16 bit pattern stands for, on an extended lattice
/// where ±inf sits at ±2¹²⁸ — the point the finite lattice would
/// continue to. Working in f64 makes every candidate and every
/// difference below exact (Sterbenz: the two candidates bracket `v`
/// within one bf16 ulp).
fn bf16_lattice_f64(b: u16) -> f64 {
    if (b >> 7) & 0xFF == 0xFF && b & 0x7F == 0 {
        let sign = if b & 0x8000 != 0 { -1.0 } else { 1.0 };
        return sign * 2f64.powi(128);
    }
    f32::from_bits((b as u32) << 16) as f64
}

/// Independent scalar round-to-nearest-even: truncate to get the
/// lower-magnitude candidate, compare exact f64 distances to both
/// magnitude-adjacent lattice points, break ties toward the even
/// (lsb-0) pattern. Deliberately shares no arithmetic with the
/// production bias-trick implementation.
fn reference_rtne(v: f32) -> u16 {
    assert!(!v.is_nan());
    let bits = v.to_bits();
    let lo = (bits >> 16) as u16;
    if bits & 0xFFFF == 0 {
        return lo; // already on the lattice (covers ±0 and ±inf)
    }
    // Sign-magnitude ordering: incrementing the pattern moves away from
    // zero, so `lo`/`hi` bracket v. `lo` can never be a NaN/inf pattern
    // here (that would make v itself NaN, excluded above).
    let hi = lo + 1;
    let vd = v as f64;
    let dl = (vd - bf16_lattice_f64(lo)).abs();
    let dh = (bf16_lattice_f64(hi) - vd).abs();
    if dl < dh {
        lo
    } else if dh < dl {
        hi
    } else if lo & 1 == 0 {
        lo
    } else {
        hi
    }
}

#[test]
fn bf16_rtne_matches_scalar_reference_on_random_bit_patterns() {
    // The conversion-correctness property behind the whole
    // mixed-precision PR: the production bias-trick rounding
    // (`bits + 0x7FFF + lsb >> 16`) must agree with an independent
    // nearest-even reference on arbitrary f32 bit patterns — normals,
    // subnormals, zeros, infinities, exact midpoints, overflow to inf —
    // and must quiet NaNs without ever producing one from a non-NaN.
    use layerpipe2::tensor::{bf16_to_f32, f32_to_bf16};
    property(500, |rng, case| {
        let bits = ((rng.index(1 << 16) as u32) << 16) | rng.index(1 << 16) as u32;
        for v in [
            f32::from_bits(bits),
            // Force an exact midpoint (ties are measure-zero otherwise)
            // and an on-lattice value from the same high half.
            f32::from_bits((bits & 0xFFFF_0000) | 0x8000),
            f32::from_bits(bits & 0xFFFF_0000),
        ] {
            let got = f32_to_bf16(v);
            if v.is_nan() {
                assert!(
                    bf16_to_f32(got).is_nan(),
                    "case {case}: NaN 0x{:08x} must stay NaN, got 0x{got:04x}",
                    v.to_bits()
                );
                assert_eq!(
                    got & 0xFF80,
                    (v.to_bits() >> 16) as u16 & 0xFF80,
                    "case {case}: NaN sign/exponent must be preserved"
                );
                continue;
            }
            let want = reference_rtne(v);
            assert_eq!(
                got,
                want,
                "case {case}: 0x{:08x} ({v:e}) rounded to 0x{got:04x}, reference says 0x{want:04x}",
                v.to_bits()
            );
            // Round-trip exactness: the chosen lattice point converts
            // back to itself (quantize ∘ widen = identity).
            assert_eq!(
                f32_to_bf16(bf16_to_f32(got)),
                got,
                "case {case}: lattice point 0x{got:04x} not a fixed point"
            );
        }
    });
}

#[test]
fn ema_reconstruction_holds_in_the_bf16_regime() {
    // Eq. 9 under mixed precision (DESIGN.md §11): with the EMA
    // accumulator stored in bf16 (widen → combine in f32 → re-round
    // once per push), reconstruction `Ŵ(t−d) = W(t) + lr_sum·Ḡ` must
    // stay within the dtype-derived tolerance of the stashed truth.
    // For a constant bf16-representable update stream the quantized
    // EMA's steady-state error is bounded by the fixed point of
    // e' = β·e + round: |Ḡ − u| ≤ eps_bf16·|u|/(1−β) = (d+1)·eps·|u|,
    // so the reconstruction error is ≤ lr_sum·(d+1)·eps_bf16·max|u|
    // — about 0.035 for the d ≤ 8, lr_sum ≤ 0.24, |u| ≲ 4 ranges
    // below; 0.06 leaves slack. The jittered bound is the f32 test's
    // 0.08 plus the same bf16 term.
    use layerpipe2::stash::WeightStash;
    use layerpipe2::tensor::Dtype;
    property(24, |rng, case| {
        let d = 1 + rng.index(8);
        let n = 4 + rng.index(8);
        let lr = 0.03f32;
        let jitter = if rng.chance(0.5) { 0.0 } else { 0.02 };
        // A bf16-representable stream makes the constant case a pure
        // accumulator-error measurement (no input-quantization term).
        let base = Tensor::randn(&[n], 1.0, rng).to_dtype(Dtype::Bf16).to_dtype(Dtype::F32);
        let mut w = Tensor::randn(&[n], 1.0, rng);
        let mut stash = WeightStash::new(d + 1);
        let mut ema = PipelineAwareEma::new_with_dtype(d, Dtype::Bf16);
        let steps = (d as u64) + 4 + rng.index(30) as u64;
        for t in 0..steps {
            stash.push(t, &w);
            let mut u = base.clone();
            if jitter > 0.0 {
                u.axpy(jitter, &Tensor::randn(&[n], 1.0, rng));
            }
            w.axpy(-lr, &u);
            ema.push(&u);
        }
        let target = stash
            .get(steps - d as u64)
            .unwrap_or_else(|| panic!("case {case}: stash must retain t-d"));
        let lr_sum = lr * d as f32;
        // reconstruct() widens the bf16 mean per element and runs the
        // axpy in f32 — never touch `ema.mean()` directly here, its
        // backing store is u16 bits.
        let recon = ema.reconstruct(&w, lr_sum);
        assert_eq!(recon.dtype(), Dtype::F32, "case {case}: reconstruction must widen");
        let recon_err = recon.max_abs_diff(target);
        let latest_err = w.max_abs_diff(target);
        if jitter == 0.0 {
            assert!(
                recon_err < 0.06,
                "case {case} d={d}: bf16 constant-stream err {recon_err} beyond \
                 lr_sum·(d+1)·eps_bf16·|u| bound"
            );
        } else {
            assert!(
                recon_err < 0.15,
                "case {case} d={d}: bf16 reconstruction err {recon_err} beyond \
                 Eq. 9 tolerance + bf16 slack"
            );
        }
        assert!(
            recon_err <= latest_err + 0.06,
            "case {case} d={d}: recon {recon_err} much worse than latest {latest_err}"
        );
    });
}

#[test]
fn ema_reconstruction_matches_stashed_weights_within_eq9_tolerance() {
    // The paper's Eq. 9 claim, as a property over random delay
    // assignments: reconstructing W(t−d) from the current weights plus
    // the delay-matched EMA of applied updates must (a) be exact for a
    // constant update stream, and (b) track the explicitly stashed
    // version closely — and strictly better than using the latest
    // weights — for a slowly-varying stream.
    use layerpipe2::stash::WeightStash;
    property(24, |rng, case| {
        let d = 1 + rng.index(8);
        let n = 4 + rng.index(8);
        let lr = 0.03f32;
        let jitter = if rng.chance(0.5) { 0.0 } else { 0.02 };
        let base = Tensor::randn(&[n], 1.0, rng);
        let mut w = Tensor::randn(&[n], 1.0, rng);
        let mut stash = WeightStash::new(d + 1);
        let mut ema = PipelineAwareEma::new(d);
        let steps = (d as u64) + 4 + rng.index(30) as u64;
        for t in 0..steps {
            stash.push(t, &w);
            let mut u = base.clone();
            if jitter > 0.0 {
                u.axpy(jitter, &Tensor::randn(&[n], 1.0, rng));
            }
            w.axpy(-lr, &u);
            ema.push(&u);
        }
        // The backward for the batch launched at t = steps − d runs now:
        // it needs W(steps − d), which stashing stored explicitly.
        let target = stash
            .get(steps - d as u64)
            .unwrap_or_else(|| panic!("case {case}: stash must retain t-d"));
        let lr_sum = lr * d as f32; // constant-lr Eq. 9 sum
        let recon = ema.reconstruct(&w, lr_sum);
        let recon_err = recon.max_abs_diff(target);
        let latest_err = w.max_abs_diff(target);
        if jitter == 0.0 {
            assert!(
                recon_err < 1e-4,
                "case {case} d={d}: constant stream must reconstruct exactly, err {recon_err}"
            );
        } else {
            assert!(
                recon_err < 0.08,
                "case {case} d={d}: reconstruction err {recon_err} beyond Eq. 9 tolerance"
            );
        }
        // Reconstruction must not be worse than skipping it (latest).
        assert!(
            recon_err <= latest_err + 0.01,
            "case {case} d={d}: recon {recon_err} much worse than latest {latest_err}"
        );
    });
}
