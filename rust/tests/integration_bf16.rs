//! Integration: the bf16 storage dtype end-to-end (DESIGN.md §11).
//!
//! The mixed-precision contract this file enforces:
//!  - every Fig. 5 strategy trains in bf16 and lands within the
//!    documented accuracy tolerance of its f32 oracle (storage
//!    rounding perturbs the trajectory, never the convergence class);
//!  - bf16 runs are bit-deterministic: repeating a run reproduces the
//!    loss curve exactly, and the weight ring yields bitwise-identical
//!    final weights at every replica count (the reduce tree widens per
//!    element and re-quantizes once, a pure function of shard count);
//!  - checkpoints round-trip: a bf16 session writes version 3 and
//!    restores bit-for-bit; v2 all-f32 files keep loading (cross
//!    version restore).
//!
//! Everything runs on the host backend — the only backend that serves
//! bf16 — so a clean checkout exercises the full machinery.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::teacher_dataset;
use layerpipe2::layers::{Network, NetworkSpec};
use layerpipe2::model::checkpoint;
use layerpipe2::replica::{train_ring, RingConfig};
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::Dtype;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn host() -> Backend {
    Arc::new(HostBackend::new())
}

fn quick_cfg(epochs: usize, dtype: Dtype) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.epochs = epochs;
    cfg.dtype = dtype;
    cfg.data = DataConfig {
        train_samples: 512,
        test_samples: 256,
        teacher_hidden: 48,
        label_noise: 0.0,
        seed: 99,
    };
    cfg
}

fn train_once(cfg: &ExperimentConfig, kind: StrategyKind) -> (Trainer, f32, Vec<f32>) {
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::new(host(), cfg, kind, &mut rng).expect("trainer init");
    let mut batch_rng = Rng::new(5);
    let curve = t.train(&teacher_dataset(&cfg.model, &cfg.data), &mut batch_rng).expect("train");
    let losses = curve.epochs.iter().map(|e| e.train_loss).collect();
    let acc = curve.final_accuracy();
    (t, acc, losses)
}

/// Documented end-to-end tolerance (DESIGN.md §11): bf16 storage keeps
/// every strategy in the same convergence class as f32 — it must still
/// clearly learn, and its final accuracy may not drift from the f32
/// oracle by more than 0.25 on this 16-class workload. The bound is
/// loose by design: per-step rounding (one quantization per parameter
/// per update, eps 2⁻⁸) compounds chaotically through the nonlinear
/// training dynamics, so only statistical closeness is meaningful at
/// the curve level — the *kernel*-level contract (k·eps_bf16 per
/// reduction, bitwise widening equivalence) lives in the unit tests.
const ACCURACY_TOLERANCE: f32 = 0.25;

#[test]
fn all_strategies_learn_in_bf16_within_tolerance_of_f32() {
    let f32_cfg = quick_cfg(3, Dtype::F32);
    let bf16_cfg = quick_cfg(3, Dtype::Bf16);
    let random_acc = 1.0 / f32_cfg.model.classes as f32;
    for &kind in StrategyKind::all() {
        let (_, acc_f32, _) = train_once(&f32_cfg, kind);
        let (_, acc_bf16, losses) = train_once(&bf16_cfg, kind);
        assert!(
            losses.iter().all(|l| l.is_finite()),
            "{}: bf16 training produced a non-finite loss",
            kind.name()
        );
        assert!(
            acc_bf16 > 2.0 * random_acc,
            "{}: no learning in bf16 (accuracy {acc_bf16})",
            kind.name()
        );
        assert!(
            (acc_f32 - acc_bf16).abs() <= ACCURACY_TOLERANCE,
            "{}: bf16 accuracy {acc_bf16} drifted more than {ACCURACY_TOLERANCE} from f32 oracle {acc_f32}",
            kind.name()
        );
    }
}

#[test]
fn bf16_training_is_bit_deterministic() {
    let cfg = quick_cfg(2, Dtype::Bf16);
    let (ta, acc_a, losses_a) = train_once(&cfg, StrategyKind::PipelineAwareEma);
    let (tb, acc_b, losses_b) = train_once(&cfg, StrategyKind::PipelineAwareEma);
    assert_eq!(acc_a.to_bits(), acc_b.to_bits(), "accuracy not reproducible");
    for (a, b) in losses_a.iter().zip(&losses_b) {
        assert_eq!(a.to_bits(), b.to_bits(), "per-epoch loss not reproducible");
    }
    for (la, lb) in ta.net.layers.iter().zip(&tb.net.layers) {
        assert_eq!(la.w.dtype(), Dtype::Bf16, "weights must store bf16");
        assert_eq!(la.w.bits(), lb.w.bits(), "weight bits not reproducible");
    }
}

#[test]
fn bf16_weights_halve_parameter_bytes() {
    let cfg = quick_cfg(1, Dtype::Bf16);
    let mut rng = Rng::new(cfg.seed);
    let t = Trainer::new(host(), &cfg, StrategyKind::Sequential, &mut rng).unwrap();
    let f32_net =
        Network::build(&NetworkSpec::mlp(&cfg.model), &mut Rng::new(cfg.seed)).unwrap();
    for (nl, fl) in t.net.layers.iter().zip(&f32_net.layers) {
        assert_eq!(nl.w.nbytes() * 2, fl.w.nbytes(), "bf16 weights must be half-width");
    }
}

/// Replica-count invariance survives the bf16 wire: the staged
/// gradients quantize once on flatten, the tree reduce widens per
/// element into an f32 mean, and the return leg re-quantizes once —
/// every stage a pure function of the shard count, so 1, 2 and 4
/// replicas produce the same bits.
#[test]
fn bf16_ring_is_bitwise_identical_across_replica_counts() {
    let mut cfg = quick_cfg(2, Dtype::Bf16);
    cfg.model.batch = 8;
    cfg.model.input_dim = 10;
    cfg.model.hidden_dim = 16;
    cfg.model.classes = 3;
    cfg.model.layers = 4;
    cfg.pipeline.stages = 2;
    cfg.data.train_samples = 64;
    cfg.data.test_samples = 16;
    cfg.data.teacher_hidden = 12;
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let shards = 4usize;
    for &kind in StrategyKind::all() {
        let oracle = train_ring(&host(), &cfg, None, kind, &RingConfig::new(1, shards), &data)
            .expect("1-replica bf16 ring");
        for replicas in [2usize, 4] {
            let r =
                train_ring(&host(), &cfg, None, kind, &RingConfig::new(replicas, shards), &data)
                    .expect("multi-replica bf16 ring");
            // `model_to_tensor` widens bf16 exactly (injective), so f32
            // flat equality is bf16 storage equality.
            assert_eq!(r.final_weights.len(), oracle.final_weights.len());
            let same = r
                .final_weights
                .data()
                .iter()
                .zip(oracle.final_weights.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(
                same,
                "{}: bf16 final weights at {replicas} replicas differ from the 1-replica oracle",
                kind.name()
            );
        }
    }
}

#[test]
fn bf16_session_checkpoints_as_v3_and_restores_bitwise() {
    let cfg = quick_cfg(1, Dtype::Bf16);
    let (t, _, _) = train_once(&cfg, StrategyKind::FixedEma);
    let bytes = checkpoint::network_to_bytes(&t.net);
    assert_eq!(
        u32::from_le_bytes(bytes[8..12].try_into().unwrap()),
        3,
        "a bf16 session must write the dtype-tagged v3 format"
    );
    let mut restored = Network::build(&NetworkSpec::mlp(&cfg.model), &mut Rng::new(0)).unwrap();
    checkpoint::network_from_bytes(&mut restored, &bytes).unwrap();
    for (a, b) in t.net.layers.iter().zip(&restored.layers) {
        assert_eq!(b.w.dtype(), Dtype::Bf16);
        assert_eq!(a.w.bits(), b.w.bits(), "restored weight bits differ");
        assert_eq!(a.b, b.b, "biases stay f32 and restore bitwise");
    }
}

#[test]
fn v2_f32_checkpoint_loads_into_a_bf16_session_net() {
    // Cross-version restore: an f32 session's v2 file loads into the
    // network of a bf16 session — tensors take the file's dtype, and
    // the kernels serve the f32/bf16 mixture without conversion.
    let f32_cfg = quick_cfg(1, Dtype::F32);
    let (tf, _, _) = train_once(&f32_cfg, StrategyKind::Sequential);
    let v2 = checkpoint::network_to_bytes(&tf.net);
    assert_eq!(u32::from_le_bytes(v2[8..12].try_into().unwrap()), 2);

    let bf16_cfg = quick_cfg(1, Dtype::Bf16);
    let mut rng = Rng::new(bf16_cfg.seed);
    let mut tb = Trainer::new(host(), &bf16_cfg, StrategyKind::Sequential, &mut rng).unwrap();
    assert_eq!(tb.net.layers[0].w.dtype(), Dtype::Bf16);
    checkpoint::network_from_bytes(&mut tb.net, &v2).unwrap();
    for (a, b) in tf.net.layers.iter().zip(&tb.net.layers) {
        assert_eq!(b.w.dtype(), Dtype::F32, "restored tensors carry the file's dtype");
        assert_eq!(a.w, b.w);
    }
}
