//! Integration: the multi-threaded pipelined executor against the
//! single-threaded iteration-indexed `Trainer` oracle.
//!
//! The executor runs one worker thread per stage, interleaving forward
//! of batch `t` with the delayed backward of batch `t − d` and applying
//! gradients stage-locally — the paper's schedule, physically executed.
//! Because each stage performs the identical sequence of f32 operations
//! as the oracle, the per-epoch loss curves must agree to tight
//! tolerance (they are bit-identical in practice) for every Fig. 5
//! strategy, per-layer and grouped partitions alike.
//!
//! Everything runs on the host backend so a clean checkout exercises the
//! full concurrency machinery.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::teacher_dataset;
use layerpipe2::metrics::RunCurve;
use layerpipe2::pipeline::PipelinedTrainer;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn host() -> Backend {
    Arc::new(HostBackend::new())
}

fn tiny_cfg(stages: usize, epochs: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 8;
    cfg.model.input_dim = 16;
    cfg.model.hidden_dim = 12;
    cfg.model.classes = 4;
    cfg.model.layers = 4;
    cfg.pipeline.stages = stages;
    cfg.epochs = epochs;
    cfg.data = DataConfig {
        train_samples: 128,
        test_samples: 64,
        teacher_hidden: 10,
        label_noise: 0.0,
        seed: 17,
    };
    cfg
}

/// Train the same (config, strategy) on both engines with the identical
/// seed discipline the coordinator uses.
fn run_both(cfg: &ExperimentConfig, kind: StrategyKind) -> (RunCurve, RunCurve) {
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let oracle = {
        let mut rng = Rng::new(cfg.seed);
        let mut t = Trainer::new(host(), cfg, kind, &mut rng).expect("oracle init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        t.train(&data, &mut batch_rng).expect("oracle train")
    };
    let threaded = {
        let mut rng = Rng::new(cfg.seed);
        let mut ex = PipelinedTrainer::new(host(), cfg, kind, &mut rng).expect("executor init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        ex.train(&data, &mut batch_rng).expect("executor train")
    };
    (oracle, threaded)
}

fn assert_curves_match(kind: StrategyKind, oracle: &RunCurve, threaded: &RunCurve, tol: f32) {
    assert_eq!(oracle.epochs.len(), threaded.epochs.len(), "{kind:?}: epoch count");
    for (e, (a, b)) in oracle.epochs.iter().zip(&threaded.epochs).enumerate() {
        if a.train_loss.is_nan() || b.train_loss.is_nan() {
            assert!(
                a.train_loss.is_nan() && b.train_loss.is_nan(),
                "{kind:?} epoch {e}: NaN mismatch ({} vs {})",
                a.train_loss,
                b.train_loss
            );
        } else {
            assert!(
                (a.train_loss - b.train_loss).abs() <= tol,
                "{kind:?} epoch {e}: oracle loss {} vs executor {}",
                a.train_loss,
                b.train_loss
            );
        }
        assert!(
            (a.test_accuracy - b.test_accuracy).abs() <= tol,
            "{kind:?} epoch {e}: oracle acc {} vs executor {}",
            a.test_accuracy,
            b.test_accuracy
        );
        assert_eq!(
            a.staleness_bytes, b.staleness_bytes,
            "{kind:?} epoch {e}: staleness accounting diverged"
        );
    }
}

#[test]
fn executor_matches_oracle_for_all_five_strategies() {
    // Per-layer pipelining (4 stages over 4 layers, delays [6,4,2,0]):
    // the acceptance bar — every Fig. 5 strategy, loss curves within
    // 1e-4 of the oracle under identical seeds and delays.
    let cfg = tiny_cfg(4, 3);
    for &kind in StrategyKind::all() {
        let (oracle, threaded) = run_both(&cfg, kind);
        assert_curves_match(kind, &oracle, &threaded, 1e-4);
    }
}

#[test]
fn executor_matches_oracle_on_grouped_partition() {
    // 2 stages over 4 layers (delays [2,2,0,0]): grouped delays share a
    // stage and the executor's per-stage workers each own two layers.
    let cfg = tiny_cfg(2, 3);
    for &kind in &[StrategyKind::Stashing, StrategyKind::PipelineAwareEma] {
        let (oracle, threaded) = run_both(&cfg, kind);
        assert_curves_match(kind, &oracle, &threaded, 1e-4);
    }
}

#[test]
fn executor_matches_oracle_with_warmup_epochs() {
    // Warm-up toggling happens at epoch barriers; both engines must
    // apply it to the same backwards.
    let mut cfg = tiny_cfg(4, 3);
    cfg.pipeline.warmup_epochs = 1;
    let (oracle, threaded) = run_both(&cfg, StrategyKind::PipelineAwareEma);
    assert_curves_match(StrategyKind::PipelineAwareEma, &oracle, &threaded, 1e-4);
}

#[test]
fn executor_handles_delay_longer_than_an_epoch_tail() {
    // 8 layers in 8 stages (max delay 14) with only 16 iterations per
    // epoch: most of an epoch is pipeline fill, batches retire across
    // epoch boundaries, and the final drain spans many idle iterations.
    let mut cfg = tiny_cfg(4, 2);
    cfg.model.layers = 8;
    cfg.model.hidden_dim = 8;
    cfg.pipeline.stages = 8;
    let (oracle, threaded) = run_both(&cfg, StrategyKind::Stashing);
    assert_curves_match(StrategyKind::Stashing, &oracle, &threaded, 1e-4);
}
