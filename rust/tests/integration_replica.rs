//! Integration: weight-ring replica parallelism under the determinism
//! contract.
//!
//! The acceptance bar of the replica-ring PR:
//!  - `train_ring` produces **bitwise identical** final weights for
//!    every replica count that divides the shard count — for all five
//!    weight-handling strategies of Fig. 5 (the reduce tree is a pure
//!    function of the shard decomposition, never of thread placement
//!    or arrival order);
//!  - the degenerate ring (1 replica, 1 shard) replays the stock
//!    `Trainer` bit for bit: deferring optimizer steps to the end of
//!    the iteration and pushing gradients through the flat ring codec
//!    changes nothing;
//!  - the ring composes with the heterogeneous layer zoo (conv + pool +
//!    dense specs train through `Trainer::with_spec` lanes).
//!
//! Everything runs on the host backend so a clean checkout exercises
//! the full machinery.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::{image_teacher_dataset, teacher_dataset, BatchIter, Splits};
use layerpipe2::layers::{Feature, LayerSpec, NetworkSpec};
use layerpipe2::replica::{model_to_tensor, train_ring, RingConfig, RingReport};
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::Tensor;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn host() -> Backend {
    Arc::new(HostBackend::new())
}

/// Small dense workload: 8 iterations/epoch x 2 epochs, batch 8.
fn dense_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 8;
    cfg.model.input_dim = 10;
    cfg.model.hidden_dim = 16;
    cfg.model.classes = 3;
    cfg.model.layers = 4;
    cfg.pipeline.stages = 2;
    cfg.epochs = 2;
    cfg.seed = 33;
    cfg.data = DataConfig {
        train_samples: 64,
        test_samples: 16,
        teacher_hidden: 12,
        label_noise: 0.0,
        seed: 99,
    };
    cfg
}

fn bits_equal(a: &Tensor, b: &Tensor) -> bool {
    a.len() == b.len()
        && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn run(cfg: &ExperimentConfig, kind: StrategyKind, replicas: usize, shards: usize, data: &Splits) -> RingReport {
    let ring = RingConfig::new(replicas, shards);
    train_ring(&host(), cfg, None, kind, &ring, data).expect("ring run")
}

/// Replica-count invariance, for every strategy: spreading the fixed
/// shard lanes over 1, 2 or 4 threads must not change a single bit of
/// the final weights.
#[test]
fn replica_counts_bitwise_identical_for_all_strategies() {
    let cfg = dense_cfg();
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let shards = 4usize;
    for &kind in StrategyKind::all() {
        let oracle = run(&cfg, kind, 1, shards, &data);
        assert!(oracle.iterations > 0, "{}: ring fed no batches", kind.name());
        for replicas in [2usize, 4] {
            let r = run(&cfg, kind, replicas, shards, &data);
            assert_eq!(
                r.iterations,
                oracle.iterations,
                "{}: iteration count changed with replica count",
                kind.name()
            );
            assert!(
                bits_equal(&r.final_weights, &oracle.final_weights),
                "{}: final weights at {} replicas differ from the single-replica oracle",
                kind.name(),
                replicas
            );
        }
    }
}

/// The degenerate ring — one replica, one shard — is the stock trainer
/// with extra plumbing (deferred steps, flat codec, identity reduce);
/// the plumbing must be bit-free. The oracle feeds a stock `Trainer`
/// by hand with the exact ring schedule: build and feed from one rng,
/// iterate every shuffled batch, drain at the very end.
#[test]
fn single_lane_ring_replays_stock_trainer_bitwise() {
    let cfg = dense_cfg();
    let data = teacher_dataset(&cfg.model, &cfg.data);
    for kind in [StrategyKind::Sequential, StrategyKind::Stashing, StrategyKind::PipelineAwareEma] {
        let mut rng = Rng::new(cfg.seed);
        let mut oracle = Trainer::new(host(), &cfg, kind, &mut rng).expect("oracle init");
        for _ in 0..cfg.epochs {
            let mut iter = BatchIter::new(&data.train, cfg.model.batch, &mut rng);
            while let Some(idx) = iter.next_indices() {
                let (x, oh) = data.train.batch(idx);
                oracle.iteration(Some((x, oh))).expect("oracle iteration");
            }
        }
        oracle.drain().expect("oracle drain");
        let mut want = Tensor::empty();
        model_to_tensor(&oracle.net, &mut want);

        let ring = run(&cfg, kind, 1, 1, &data);
        assert!(
            bits_equal(&ring.final_weights, &want),
            "{}: ring(1,1) drifted from the stock trainer",
            kind.name()
        );
    }
}

/// The ring over a heterogeneous conv+pool+dense spec: replica-count
/// invariance must survive the layer zoo (im2col workspaces, pooling
/// argmax masks, cost-balanced partitions).
#[test]
fn conv_spec_ring_is_replica_count_invariant() {
    let (h, w, c, classes) = (6usize, 6usize, 1usize, 3usize);
    let spec = NetworkSpec {
        input: Feature::Image { h, w, c },
        layers: vec![
            LayerSpec::Conv2d { out_c: 3, k: 3, stride: 1, pad: 1, relu: true },
            LayerSpec::MaxPool2d { k: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 12, relu: true },
            LayerSpec::Dense { units: classes, relu: false },
        ],
        init_scale: 1.0,
    };
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 8;
    cfg.model.input_dim = h * w * c;
    cfg.model.hidden_dim = 12;
    cfg.model.classes = classes;
    cfg.model.layers = spec.layers.len();
    cfg.pipeline.stages = 2;
    cfg.epochs = 1;
    cfg.seed = 5;
    cfg.data = DataConfig {
        train_samples: 48,
        test_samples: 16,
        teacher_hidden: 12,
        label_noise: 0.0,
        seed: 77,
    };
    let data = image_teacher_dataset(h, w, c, classes, &cfg.data);

    let kind = StrategyKind::PipelineAwareEma;
    let ring1 = RingConfig::new(1, 2);
    let ring2 = RingConfig::new(2, 2);
    let a = train_ring(&host(), &cfg, Some(&spec), kind, &ring1, &data).expect("1-replica conv ring");
    let b = train_ring(&host(), &cfg, Some(&spec), kind, &ring2, &data).expect("2-replica conv ring");
    assert!(
        bits_equal(&a.final_weights, &b.final_weights),
        "conv ring weights differ between 1 and 2 replicas"
    );
}

/// Report bookkeeping: iteration/sample counts follow from the config,
/// throughput is positive and accuracy is a probability.
#[test]
fn ring_report_accounting_is_consistent() {
    let cfg = dense_cfg();
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let r = run(&cfg, StrategyKind::FixedEma, 2, 4, &data);
    let per_epoch = (cfg.data.train_samples / cfg.model.batch) as u64;
    assert_eq!(r.iterations, per_epoch * cfg.epochs as u64);
    assert_eq!(r.samples, r.iterations * cfg.model.batch as u64);
    assert_eq!(r.replicas, 2);
    assert_eq!(r.shards, 4);
    assert!(r.samples_per_sec > 0.0);
    assert!(r.seconds >= 0.0);
    assert!((0.0..=1.0).contains(&r.test_accuracy), "accuracy {}", r.test_accuracy);
    assert!(r.train_loss.is_finite(), "loss {}", r.train_loss);
    assert_eq!(r.final_weights.len(), {
        let mut t = Tensor::empty();
        let net = layerpipe2::layers::Network::build(
            &NetworkSpec::mlp(&cfg.model),
            &mut Rng::new(cfg.seed),
        )
        .unwrap();
        model_to_tensor(&net, &mut t);
        t.len()
    });
}

/// Chaos hook: `LAYERPIPE2_FAULT_RING=<seed>` makes every ring
/// participant inject short seeded stalls at the top of its link phase.
/// Stalls reorder *time*, never data — the lockstep protocol and
/// ordered channels mean the final weights must stay bitwise identical
/// to the un-faulted oracle. (If the hook leaks into a concurrently
/// running ring test, that test's invariants still hold for the same
/// reason; the stalls only slow it down.)
#[test]
fn injected_ring_stalls_never_change_weights() {
    let cfg = dense_cfg();
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let kind = StrategyKind::PipelineAwareEma;
    let oracle = run(&cfg, kind, 2, 4, &data);
    let before = layerpipe2::obs::counter("ring/faults_injected").value();
    std::env::set_var(layerpipe2::replica::FAULT_RING_ENV, "1234");
    let faulted = run(&cfg, kind, 2, 4, &data);
    std::env::remove_var(layerpipe2::replica::FAULT_RING_ENV);
    let injected = layerpipe2::obs::counter("ring/faults_injected").value() - before;
    assert!(injected > 0, "fault hook armed but never fired");
    assert_eq!(faulted.iterations, oracle.iterations);
    assert!(
        bits_equal(&faulted.final_weights, &oracle.final_weights),
        "injected stalls changed the final weights (determinism broken)"
    );
}

/// Invalid ring shapes are rejected up front, not mid-run.
#[test]
fn ring_config_rejects_bad_shapes() {
    let cfg = dense_cfg();
    let data = teacher_dataset(&cfg.model, &cfg.data);
    // 3 shards do not divide batch 8.
    let bad = RingConfig::new(1, 3);
    assert!(train_ring(&host(), &cfg, None, StrategyKind::Latest, &bad, &data).is_err());
    // 3 replicas do not divide 4 shards.
    let bad = RingConfig::new(3, 4);
    assert!(train_ring(&host(), &cfg, None, StrategyKind::Latest, &bad, &data).is_err());
}
