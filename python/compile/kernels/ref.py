"""Pure-jnp oracles for every compute graph in the artifact set.

These are the correctness ground truth: pytest checks the Pallas kernels
and the per-layer model functions against them (and against ``jax.grad``)
before anything is lowered for the Rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_bias_ref(x, w, b=None, epilogue: str = "none"):
    """Reference for kernels.matmul.matmul_bias."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b[None, :]
    if epilogue == "relu":
        y = jnp.maximum(y, 0.0)
    return y


def dense_fwd_ref(x, w, b, relu: bool):
    """Forward of one dense layer: ``act(x @ w + b)``."""
    return matmul_bias_ref(x, w, b, "relu" if relu else "none")


def dense_bwd_ref(x, y, w, dy, relu: bool):
    """Backward of one dense layer given its saved input ``x``, saved
    output ``y`` (for the ReLU mask), weights and upstream grad ``dy``.

    Returns ``(dx, dw, db)``.
    """
    dz = jnp.where(y > 0, dy, 0.0) if relu else dy
    dx = jnp.dot(dz, w.T)
    dw = jnp.dot(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


def loss_grad_ref(logits, onehot):
    """Mean softmax cross-entropy, gradient wrt logits, #correct rows.

    ``onehot`` is the f32 one-hot label matrix (kept one-hot so the HLO
    artifact avoids integer gathers).
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    p = jnp.exp(logp)
    dlogits = (p - onehot) / logits.shape[0]
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(onehot, axis=-1)).astype(
            jnp.float32
        )
    )
    return loss, dlogits, correct


def mlp_loss_ref(params, x, onehot):
    """End-to-end loss of the full MLP (for jax.grad cross-checks).

    ``params`` is a list of ``(w, b)`` tuples; ReLU on all but the last.
    """
    h = x
    for i, (w, b) in enumerate(params):
        relu = i < len(params) - 1
        h = dense_fwd_ref(h, w, b, relu)
    loss, _, _ = loss_grad_ref(h, onehot)
    return loss
