"""L1: blocked Pallas matmul kernels (the training hot-spot).

The paper's workload ran dense/conv compute through cuDNN on an RTX 3070
Ti. The TPU rethink (DESIGN.md §Hardware-Adaptation): express the tiled
matmul as a Pallas kernel whose ``BlockSpec`` grid encodes the HBM->VMEM
schedule CUDA would express with threadblocks/shared memory, accumulating
over the K grid axis in f32 with a ``@pl.when`` zero-init prologue and a
fused bias(+ReLU) epilogue applied in VMEM on the last K step (avoiding
an HBM round trip for the activation).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO so
the AOT artifacts are executable from the Rust runtime. Kernel structure
(block shapes, VMEM footprint, MXU-friendly tiles) is what we optimize;
interpret-mode wallclock is irrelevant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles (128x128 systolic array). Clamped per-shape.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _block_dims(m: int, n: int, k: int,
                bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK) -> tuple[int, int, int]:
    """Clamp tile sizes to the problem, preferring exact divisors.

    For paper-scale shapes (<=128 per dim) the tile covers the whole
    operand and the grid is (1,1,1); for larger shapes we shrink to the
    largest divisor <= the default tile so no masking is needed.
    """

    def clamp(dim: int, blk: int) -> int:
        if dim <= blk:
            return dim
        b = blk
        while dim % b != 0:
            b -= 1
        return b

    return clamp(m, bm), clamp(n, bn), clamp(k, bk)


def _make_kernel(k_steps: int, epilogue: str, with_bias: bool):
    """Build the grid-step body.

    The f32 output block doubles as the K-loop accumulator (zero-inited on
    the first K step via ``@pl.when``); bias/ReLU fuse into the final K
    step so the activation is produced in VMEM in one pass.
    """

    def body(*refs):
        if with_bias:
            x_ref, w_ref, b_ref, o_ref = refs
        else:
            (x_ref, w_ref, o_ref), b_ref = refs, None
        kk = pl.program_id(2)

        @pl.when(kk == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

        @pl.when(kk == k_steps - 1)
        def _epilogue():
            acc = o_ref[...]
            if b_ref is not None:
                acc = acc + b_ref[...][None, :]
            if epilogue == "relu":
                acc = jnp.maximum(acc, 0.0)
            o_ref[...] = acc

    return body


def matmul_bias(x: jax.Array, w: jax.Array, b: jax.Array | None,
                epilogue: str = "none") -> jax.Array:
    """``x @ w (+ b)`` with optional fused ReLU, as a blocked Pallas call.

    Args:
      x: ``[m, k]`` input.
      w: ``[k, n]`` weights.
      b: ``[n]`` bias or ``None``.
      epilogue: ``"none"`` or ``"relu"``.
    """
    if epilogue not in ("none", "relu"):
        raise ValueError(f"unknown epilogue {epilogue!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    if b is not None and b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")

    bm, bn, bk = _block_dims(m, n, k)
    grid = (m // bm, n // bn, k // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        operands.append(b)

    return pl.pallas_call(
        _make_kernel(grid[2], epilogue, with_bias=b is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(*operands)


@functools.partial(jax.jit, static_argnames=("epilogue",))
def matmul(x: jax.Array, w: jax.Array, epilogue: str = "none") -> jax.Array:
    """``x @ w`` with an optional fused ReLU epilogue (no bias)."""
    return matmul_bias(x, w, None, epilogue=epilogue)


def vmem_bytes(m: int, n: int, k: int) -> int:
    """Estimated VMEM working set per grid step (DESIGN.md §Perf L1)."""
    bm, bn, bk = _block_dims(m, n, k)
    return 4 * (bm * bk + bk * bn + bm * bn + bn)


def arithmetic_intensity(m: int, n: int, k: int) -> float:
    """FLOPs per byte moved HBM->VMEM per grid step (MXU-bound when high)."""
    bm, bn, bk = _block_dims(m, n, k)
    flops = 2.0 * bm * bn * bk
    bytes_moved = 4.0 * (bm * bk + bk * bn)
    return flops / bytes_moved
