"""L2: per-layer JAX compute graphs of the training workload.

Each function here is a *compilation unit*: ``aot.py`` lowers every entry
point once, at fixed shapes, to HLO text that the Rust coordinator loads
through PJRT. The functions call the L1 Pallas kernel
(:mod:`compile.kernels.matmul`) for every matmul so the kernel lowers
into the same HLO module.

The per-layer split (rather than one fused train step) is what makes
multistage pipelining possible at L3: the Rust trainer owns weights,
stashes, EMA state and the delayed-gradient schedule, and invokes
``dense_fwd_*`` / ``dense_bwd_*`` / ``loss_grad`` per stage per clock.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul_bias
from .kernels import ref


def dense_fwd(x, w, b, *, relu: bool):
    """Forward of one dense layer: ``act(x @ w + b)``.

    Returns a 1-tuple ``(y,)`` (all artifacts return tuples so the Rust
    side can unwrap uniformly).
    """
    y = matmul_bias(x, w, b, epilogue="relu" if relu else "none")
    return (y,)


def dense_bwd(x, y, w, dy, *, relu: bool):
    """Backward of one dense layer.

    Args:
      x: saved layer input (stashed at forward time by the L3 trainer).
      y: saved layer output (ReLU mask source; ignored when linear).
      w: the weight version *chosen by the weight-handling strategy* —
         stashed, latest, or EMA-reconstructed (paper Fig. 5).
      dy: upstream gradient.

    Returns ``(dx, dw, db)``.
    """
    dz = jnp.where(y > 0, dy, 0.0) if relu else dy
    dx = matmul_bias(dz, w.T, None)
    dw = matmul_bias(x.T, dz, None)
    db = jnp.sum(dz, axis=0)
    return (dx, dw, db)


def dense_bwd_linear(x, w, dy):
    """Backward of the output (linear) layer — no saved output needed."""
    dx = matmul_bias(dy, w.T, None)
    dw = matmul_bias(x.T, dy, None)
    db = jnp.sum(dy, axis=0)
    return (dx, dw, db)


def loss_grad(logits, onehot):
    """Mean softmax cross-entropy + initial gradient + #correct.

    Labels arrive one-hot (f32) to keep the artifact gather-free.
    """
    return ref.loss_grad_ref(logits, onehot)


def fwd_full(x, *params_flat):
    """Fused full-network forward (eval hot path).

    ``params_flat`` is ``w0, b0, w1, b1, …``; ReLU on all but the last
    layer. One artifact instead of L dispatches for test-set evaluation.
    """
    assert len(params_flat) % 2 == 0
    layers = len(params_flat) // 2
    h = x
    for i in range(layers):
        w, b = params_flat[2 * i], params_flat[2 * i + 1]
        (h,) = dense_fwd(h, w, b, relu=i < layers - 1)
    return (h,)


def train_step_reference(params, x, onehot, lr):
    """Fused sequential SGD step (reference/ablation artifact).

    Used by tests to cross-check the L3 per-layer pipeline against a
    monolithic jax.grad step, and by the sequential-throughput ablation.
    Returns ``(loss, *new_params_flat)``.
    """
    loss, grads = jax.value_and_grad(ref.mlp_loss_ref)(params, x, onehot)
    new_flat = []
    for (w, b), (gw, gb) in zip(params, grads):
        new_flat.append(w - lr * gw)
        new_flat.append(b - lr * gb)
    return (loss, *new_flat)
