"""AOT compiler: lower every L2 entry point to HLO text + manifest.

Runs once at build time (``make artifacts``); Python never touches the
training path. The interchange format is HLO *text*, not a serialized
``HloModuleProto``: jax >= 0.5 emits protos with 64-bit instruction ids
that the Rust side's xla_extension 0.5.1 rejects, while the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts --preset small
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shape presets. ``small`` is the Fig. 5 experiment scale (DESIGN.md
# substitutions: an 8-layer MLP giving the paper's 8 scheduling units);
# ``tiny`` keeps python tests fast; ``paper`` is the throughput-model
# scale used for VMEM/MXU estimates.
PRESETS = {
    "tiny": dict(batch=4, input_dim=8, hidden_dim=8, classes=4, layers=3),
    "small": dict(batch=32, input_dim=64, hidden_dim=64, classes=16, layers=8),
    "paper": dict(batch=128, input_dim=256, hidden_dim=512, classes=100, layers=8),
}


def f32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries_for(cfg: dict):
    """The artifact set: (name, python callable, example-arg specs)."""
    b, d, h, c, layers = (
        cfg["batch"],
        cfg["input_dim"],
        cfg["hidden_dim"],
        cfg["classes"],
        cfg["layers"],
    )
    assert layers >= 2

    def fwd_relu(x, w, bb):
        return model.dense_fwd(x, w, bb, relu=True)

    def fwd_linear(x, w, bb):
        return model.dense_fwd(x, w, bb, relu=False)

    def bwd_relu(x, y, w, dy):
        return model.dense_bwd(x, y, w, dy, relu=True)

    ents = [
        ("dense_fwd_in", fwd_relu, [f32(b, d), f32(d, h), f32(h)]),
        ("dense_fwd_hid", fwd_relu, [f32(b, h), f32(h, h), f32(h)]),
        ("dense_fwd_out", fwd_linear, [f32(b, h), f32(h, c), f32(c)]),
        ("dense_bwd_in", bwd_relu, [f32(b, d), f32(b, h), f32(d, h), f32(b, h)]),
        ("dense_bwd_hid", bwd_relu, [f32(b, h), f32(b, h), f32(h, h), f32(b, h)]),
        ("dense_bwd_out", model.dense_bwd_linear, [f32(b, h), f32(h, c), f32(b, c)]),
        ("loss_grad", model.loss_grad, [f32(b, c), f32(b, c)]),
    ]

    # Ablation artifact: the same hidden-layer forward lowered from the
    # pure-jnp reference instead of the Pallas kernel. Used by the perf
    # harness to quantify the interpret-mode lowering overhead on CPU
    # (real-TPU Mosaic lowering does not pay it). Never on the train path.
    def fwd_hid_jnp(x, w, bb):
        from .kernels import ref

        return (ref.dense_fwd_ref(x, w, bb, relu=True),)

    ents.append(("ablation_fwd_hid_jnp", fwd_hid_jnp, [f32(b, h), f32(h, h), f32(h)]))

    # Fused full-forward for evaluation: x + (w, b) per layer.
    full_specs = [f32(b, d)]
    for i in range(layers):
        din = d if i == 0 else h
        dout = c if i == layers - 1 else h
        full_specs += [f32(din, dout), f32(dout)]
    ents.append(("fwd_full", model.fwd_full, full_specs))
    return ents


def lower_entry(fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    # Output arity = tuple length of an abstract eval.
    out = jax.eval_shape(fn, *specs)
    arity = len(out) if isinstance(out, tuple) else 1
    return text, arity, out


def source_fingerprint() -> str:
    """Hash of the compile-path sources, recorded for staleness checks."""
    here = os.path.dirname(__file__)
    paths = [
        os.path.join(here, "model.py"),
        os.path.join(here, "aot.py"),
        os.path.join(here, "kernels", "matmul.py"),
        os.path.join(here, "kernels", "ref.py"),
    ]
    hsh = hashlib.sha256()
    for p in sorted(paths):
        with open(p, "rb") as f:
            hsh.update(f.read())
    return hsh.hexdigest()[:16]


def build(out_dir: str, preset: str) -> dict:
    cfg = PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "preset": preset,
        "model": cfg,
        "fingerprint": source_fingerprint(),
        "entries": [],
    }
    for name, fn, specs in entries_for(cfg):
        text, arity, out = lower_entry(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(s.shape) for s in specs],
                "outputs": arity,
                "output_shapes": [list(o.shape) for o in (out if isinstance(out, tuple) else (out,))],
            }
        )
        print(f"  lowered {name}: {len(text)} chars, {len(specs)} inputs, {arity} outputs")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {out_dir}/manifest.json ({len(manifest['entries'])} entries)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    args = ap.parse_args()
    build(args.out_dir, args.preset)


if __name__ == "__main__":
    main()
