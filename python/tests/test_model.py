"""L2 correctness: per-layer fwd/bwd graphs vs jax.grad ground truth.

The pipelined trainer composes per-layer artifacts; these tests prove
that composition is *exactly* backpropagation when no delay is applied —
the invariant that makes the sequential strategy a true reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def make_params(key, dims):
    params = []
    for i in range(len(dims) - 1):
        key, k1, k2 = jax.random.split(key, 3)
        params.append((
            jax.random.normal(k1, (dims[i], dims[i + 1])) / np.sqrt(dims[i]),
            jax.random.normal(k2, (dims[i + 1],)) * 0.01,
        ))
    return params


def onehot(labels, classes):
    return jax.nn.one_hot(labels, classes, dtype=jnp.float32)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(7)
    dims = [8, 16, 12, 6]
    params = make_params(key, dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 8))
    y = onehot(jnp.arange(10) % 6, 6)
    return params, x, y


def test_per_layer_fwd_matches_ref(setup):
    params, x, _ = setup
    h = x
    for i, (w, b) in enumerate(params):
        relu = i < len(params) - 1
        (got,) = model.dense_fwd(h, w, b, relu=relu)
        want = ref.dense_fwd_ref(h, w, b, relu)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   atol=1e-5, rtol=1e-5)
        h = want


def test_fwd_full_equals_layer_chain(setup):
    params, x, _ = setup
    flat = [t for wb in params for t in wb]
    (full,) = model.fwd_full(x, *flat)
    h = x
    for i, (w, b) in enumerate(params):
        (h,) = model.dense_fwd(h, w, b, relu=i < len(params) - 1)
    np.testing.assert_allclose(np.array(full), np.array(h),
                               atol=1e-5, rtol=1e-5)


def test_composed_backward_equals_jax_grad(setup):
    """Chain loss_grad + per-layer dense_bwd and compare every dW, db
    against jax.grad of the monolithic loss."""
    params, x, y = setup
    L = len(params)

    # Forward, saving (input, output) per layer like the Rust trainer.
    saved = []
    h = x
    for i, (w, b) in enumerate(params):
        (out,) = model.dense_fwd(h, w, b, relu=i < L - 1)
        saved.append((h, out))
        h = out

    loss, dlogits, _ = model.loss_grad(h, y)

    # Backward chain.
    grads = [None] * L
    dy = dlogits
    for i in reversed(range(L)):
        xin, yout = saved[i]
        w, b = params[i]
        if i == L - 1:
            dx, dw, db = model.dense_bwd_linear(xin, w, dy)
        else:
            dx, dw, db = model.dense_bwd(xin, yout, w, dy, relu=True)
        grads[i] = (dw, db)
        dy = dx

    ref_loss, ref_grads = jax.value_and_grad(ref.mlp_loss_ref)(params, x, y)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    for i in range(L):
        np.testing.assert_allclose(np.array(grads[i][0]), np.array(ref_grads[i][0]),
                                   atol=1e-5, rtol=1e-4, err_msg=f"dW layer {i}")
        np.testing.assert_allclose(np.array(grads[i][1]), np.array(ref_grads[i][1]),
                                   atol=1e-5, rtol=1e-4, err_msg=f"db layer {i}")


@settings(max_examples=15, deadline=None)
@given(batch=st.integers(2, 16), din=st.integers(2, 24),
       dout=st.integers(2, 24), seed=st.integers(0, 2**31 - 1),
       relu=st.booleans())
def test_dense_bwd_matches_vjp(batch, din, dout, seed, relu):
    """Property: per-layer backward == jax.vjp of the forward, for any
    shape — including the ReLU mask path through the saved output."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    x = jax.random.normal(k1, (batch, din))
    w = jax.random.normal(k2, (din, dout)) / np.sqrt(din)
    b = jax.random.normal(k3, (dout,)) * 0.1
    dy = jax.random.normal(k4, (batch, dout))

    def f(x, w, b):
        return ref.dense_fwd_ref(x, w, b, relu)

    y, vjp = jax.vjp(f, x, w, b)
    want_dx, want_dw, want_db = vjp(dy)
    if relu:
        got_dx, got_dw, got_db = model.dense_bwd(x, y, w, dy, relu=True)
    else:
        got_dx, got_dw, got_db = model.dense_bwd_linear(x, w, dy)
    np.testing.assert_allclose(np.array(got_dx), np.array(want_dx), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.array(got_dw), np.array(want_dw), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.array(got_db), np.array(want_db), atol=1e-4, rtol=1e-4)


def test_loss_grad_correct_count_and_fd():
    logits = jnp.array([[5.0, 0.0, 0.0], [0.0, 5.0, 0.0], [0.0, 0.1, 5.0]])
    y = onehot(jnp.array([0, 1, 1]), 3)
    loss, dlogits, correct = model.loss_grad(logits, y)
    assert float(correct) == 2.0
    # Finite-difference check of dlogits.
    eps = 1e-3
    g = np.array(dlogits)
    for i in range(3):
        for j in range(3):
            lp = logits.at[i, j].add(eps)
            lm = logits.at[i, j].add(-eps)
            fd = (float(model.loss_grad(lp, y)[0]) -
                  float(model.loss_grad(lm, y)[0])) / (2 * eps)
            assert abs(fd - g[i, j]) < 1e-3


def test_train_step_reference_reduces_loss(setup):
    params, x, y = setup
    out = model.train_step_reference(params, x, y, 0.5)
    loss0 = float(out[0])
    flat = out[1:]
    new_params = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(params))]
    loss1 = float(ref.mlp_loss_ref(new_params, x, y))
    assert loss1 < loss0
