"""L1 correctness: the Pallas matmul kernel vs the pure-jnp oracle.

This is the CORE kernel correctness signal: hypothesis sweeps shapes
(including non-tile-aligned and larger-than-tile dims) and both epilogues
against ``ref.matmul_bias_ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import (
    _block_dims,
    arithmetic_intensity,
    matmul,
    matmul_bias,
    vmem_bytes,
)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


dims = st.integers(min_value=1, max_value=96)


@settings(max_examples=30, deadline=None)
@given(m=dims, k=dims, n=dims,
       epilogue=st.sampled_from(["none", "relu"]),
       with_bias=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kernel_matches_ref_random_shapes(m, k, n, epilogue, with_bias, seed):
    x = rand(seed, m, k)
    w = rand(seed + 1, k, n)
    b = rand(seed + 2, n) if with_bias else None
    got = matmul_bias(x, w, b, epilogue=epilogue)
    want = ref.matmul_bias_ref(x, w, b, epilogue)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),    # exactly one MXU tile
    (256, 384, 128),    # multi-tile K loop
    (32, 64, 16),       # the `small` preset shapes
    (1, 1, 1),          # degenerate
    (130, 66, 34),      # awkward non-power-of-two
])
def test_kernel_matches_ref_fixed_shapes(m, k, n):
    x = rand(0, m, k)
    w = rand(1, k, n)
    b = rand(2, n)
    got = matmul_bias(x, w, b, epilogue="relu")
    want = ref.matmul_bias_ref(x, w, b, "relu")
    np.testing.assert_allclose(np.array(got), np.array(want),
                               atol=1e-3, rtol=1e-4)


def test_matmul_no_bias_wrapper():
    x, w = rand(3, 16, 8), rand(4, 8, 12)
    np.testing.assert_allclose(
        np.array(matmul(x, w)),
        np.array(ref.matmul_bias_ref(x, w)),
        atol=1e-5, rtol=1e-5)


def test_relu_epilogue_clamps_negative():
    x = -jnp.ones((4, 4), jnp.float32)
    w = jnp.eye(4, dtype=jnp.float32)
    y = matmul_bias(x, w, None, epilogue="relu")
    assert np.array(y).max() == 0.0


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_bias(rand(0, 4, 5), rand(1, 6, 7), None)
    with pytest.raises(ValueError):
        matmul_bias(rand(0, 4, 5), rand(1, 5, 7), rand(2, 8))
    with pytest.raises(ValueError):
        matmul_bias(rand(0, 4, 5), rand(1, 5, 7), None, epilogue="gelu")


def test_block_dims_divide_evenly():
    for (m, k, n) in [(256, 384, 512), (130, 66, 34), (7, 11, 13)]:
        bm, bn, bk = _block_dims(m, n, k)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert bm <= 128 and bn <= 128 and bk <= 128


def test_vmem_budget_within_tpu_limits():
    # One grid step's working set must fit a 16 MiB VMEM with headroom
    # for double-buffering (DESIGN.md §Hardware-Adaptation).
    assert vmem_bytes(128, 128, 128) * 2 < 16 * 2**20
    assert vmem_bytes(4096, 4096, 4096) * 2 < 16 * 2**20


def test_arithmetic_intensity_is_mxu_bound_at_tile_scale():
    # 128^3 tile: 2*128^3 flops / (2*128^2*4) bytes = 32 flops/byte.
    assert arithmetic_intensity(128, 128, 128) == pytest.approx(32.0)
    # Paper-scale hidden layer stays compute-dense.
    assert arithmetic_intensity(128, 512, 512) >= 16.0


def test_kernel_lowers_to_plain_hlo():
    # interpret=True must produce HLO with no custom-calls, or the Rust
    # CPU PJRT client cannot execute the artifact.
    lowered = jax.jit(lambda x, w: matmul_bias(x, w, None)).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
    )
    text = lowered.compiler_ir("stablehlo")
    assert "mosaic" not in str(text).lower()
    assert "custom_call" not in str(text).lower()
