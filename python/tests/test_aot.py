"""AOT artifact generation: manifest integrity and HLO-text validity."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, "tiny")
    return out, manifest


def test_manifest_lists_all_entries(built):
    out, manifest = built
    names = {e["name"] for e in manifest["entries"]}
    assert names == {
        "dense_fwd_in", "dense_fwd_hid", "dense_fwd_out",
        "dense_bwd_in", "dense_bwd_hid", "dense_bwd_out", "ablation_fwd_hid_jnp",
        "loss_grad", "fwd_full",
    }
    with open(os.path.join(out, "manifest.json")) as f:
        ondisk = json.load(f)
    assert ondisk == manifest


def test_hlo_files_exist_and_parse_as_hlo_text(built):
    out, manifest = built
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), e["name"]
        text = open(path).read()
        assert text.startswith("HloModule"), e["name"]
        assert "ENTRY" in text, e["name"]
        # The rust CPU client cannot run custom-calls.
        assert "custom-call" not in text, e["name"]


def test_shapes_match_preset(built):
    _, manifest = built
    cfg = aot.PRESETS["tiny"]
    by_name = {e["name"]: e for e in manifest["entries"]}
    b, d, h, c = cfg["batch"], cfg["input_dim"], cfg["hidden_dim"], cfg["classes"]
    assert by_name["dense_fwd_in"]["inputs"] == [[b, d], [d, h], [h]]
    assert by_name["dense_fwd_hid"]["inputs"] == [[b, h], [h, h], [h]]
    assert by_name["dense_fwd_out"]["inputs"] == [[b, h], [h, c], [c]]
    assert by_name["loss_grad"]["inputs"] == [[b, c], [b, c]]
    assert by_name["dense_bwd_hid"]["outputs"] == 3
    # fwd_full: x + 2 tensors per layer.
    assert len(by_name["fwd_full"]["inputs"]) == 1 + 2 * cfg["layers"]


def test_output_shapes_recorded(built):
    _, manifest = built
    by_name = {e["name"]: e for e in manifest["entries"]}
    cfg = aot.PRESETS["tiny"]
    b, h, c = cfg["batch"], cfg["hidden_dim"], cfg["classes"]
    assert by_name["dense_fwd_hid"]["output_shapes"] == [[b, h]]
    assert by_name["loss_grad"]["output_shapes"] == [[], [b, c], []]


def test_fingerprint_is_stable(built):
    _, manifest = built
    assert manifest["fingerprint"] == aot.source_fingerprint()
    assert len(manifest["fingerprint"]) == 16


def test_rejects_unknown_preset():
    with pytest.raises(KeyError):
        aot.build("/tmp/nonexistent_out", "huge")
