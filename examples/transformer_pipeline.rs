//! Transformer-style pipelined training, end to end: an
//! Embedding → [SelfAttention → LayerNorm → Dense] × 2 stack on
//! token-sequence teacher data, executed by the multi-threaded
//! `PipelinedTrainer` with stage boundaries chosen by cost-balanced
//! compute over the new attention/embedding/layernorm `LayerCost`
//! reports, checked batch-for-batch against the iteration-indexed
//! `Trainer` oracle for **all five** weight-version strategies.
//!
//!     cargo run --release --example transformer_pipeline
//!     LAYERPIPE2_SMOKE=1 cargo run --release --example transformer_pipeline   # CI smoke
//!
//! What it demonstrates:
//!   1. the `2·S(l)` delay rule generalizes unchanged to attention
//!      stacks (delays depend only on downstream stage count);
//!   2. the masked softmax keeps causal attention finite end to end;
//!   3. threaded execution ≡ the oracle (≤ 1e-4) for every strategy;
//!   4. the stack actually learns the token-teacher task.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::{token_teacher_dataset, Splits};
use layerpipe2::layers::{Feature, LayerSpec, Network, NetworkSpec};
use layerpipe2::pipeline::PipelinedTrainer;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn smoke() -> bool {
    std::env::var_os("LAYERPIPE2_SMOKE").is_some()
        || std::env::var_os("LAYERPIPE2_BENCH_SMOKE").is_some()
}

fn backend() -> Backend {
    Arc::new(HostBackend::new())
}

/// Two-block causal transformer over `seq` tokens of `d_model` features.
fn transformer_spec(seq: usize, d_model: usize, vocab: usize, classes: usize) -> NetworkSpec {
    let mut layers = vec![LayerSpec::Embedding { vocab, dim: d_model }];
    for _ in 0..2 {
        layers.push(LayerSpec::SelfAttention { seq, d_model, causal: true });
        layers.push(LayerSpec::LayerNorm { eps: 1e-5 });
        layers.push(LayerSpec::Dense { units: seq * d_model, relu: true });
    }
    layers.push(LayerSpec::Dense { units: classes, relu: false });
    NetworkSpec { input: Feature::Flat(seq), layers, init_scale: 1.0 }
}

/// Train on both engines with one strategy; return (oracle acc, worst gap).
fn run_strategy(
    cfg: &ExperimentConfig,
    spec: &NetworkSpec,
    data: &Splits,
    kind: StrategyKind,
) -> (f32, f32) {
    let oracle = {
        let mut rng = Rng::new(cfg.seed);
        let mut t = Trainer::with_spec(backend(), cfg, spec, kind, &mut rng).expect("oracle init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        t.train(data, &mut batch_rng).expect("oracle train")
    };
    let threaded = {
        let mut rng = Rng::new(cfg.seed);
        let mut ex =
            PipelinedTrainer::with_spec(backend(), cfg, spec, kind, &mut rng).expect("executor init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        ex.train(data, &mut batch_rng).expect("executor train")
    };
    let mut worst = 0.0f32;
    for (a, b) in oracle.epochs.iter().zip(&threaded.epochs) {
        assert!(!a.train_loss.is_nan(), "{kind:?}: oracle loss went NaN");
        assert!(!b.train_loss.is_nan(), "{kind:?}: executor loss went NaN");
        worst = worst.max((a.train_loss - b.train_loss).abs());
        worst = worst.max((a.test_accuracy - b.test_accuracy).abs());
    }
    assert!(worst <= 1e-4, "{kind:?}: executor diverged from oracle (worst gap {worst})");
    (oracle.final_accuracy(), worst)
}

fn main() {
    let smoke = smoke();
    if smoke {
        println!("[smoke mode: reduced samples and epochs]");
    }
    let (train_n, test_n, epochs) = if smoke { (128, 64, 2) } else { (512, 256, 6) };

    let (seq, d_model, vocab, classes) = (8usize, 8usize, 16usize, 4usize);
    let spec = transformer_spec(seq, d_model, vocab, classes);

    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 16;
    cfg.model.input_dim = seq;
    cfg.model.classes = classes;
    cfg.model.layers = spec.layers.len();
    cfg.model.hidden_dim = seq * d_model; // informational for this spec
    cfg.pipeline.stages = 3;
    cfg.epochs = epochs;
    cfg.seed = 13;
    cfg.data = DataConfig {
        train_samples: train_n,
        test_samples: test_n,
        teacher_hidden: 24,
        label_noise: 0.0,
        seed: 2026,
    };
    let data = token_teacher_dataset(seq, vocab, classes, &cfg.data);

    // Cost reports and the partition they induce.
    let net = Network::build(&spec, &mut Rng::new(cfg.seed)).expect("spec builds");
    let costs: Vec<u64> = net.costs(cfg.model.batch).iter().map(|c| c.total_flops()).collect();
    println!("\n=== causal transformer ({} layers, {} stages) ===", net.num_layers(), cfg.pipeline.stages);
    for (l, nl) in net.layers.iter().enumerate() {
        println!("  layer {l}: {:<40} {:>12} flop/iter", nl.op.name(), costs[l]);
    }
    {
        let mut rng = Rng::new(cfg.seed);
        let t = Trainer::with_spec(backend(), &cfg, &spec, StrategyKind::PipelineAwareEma, &mut rng)
            .expect("trainer init");
        println!(
            "  partition (cost-balanced): {:?}  delays: {:?}",
            t.partition().stage_of(),
            t.gradient_delays()
        );
    }

    let mut final_acc = 0.0f32;
    for &kind in StrategyKind::all() {
        let (acc, worst) = run_strategy(&cfg, &spec, &data, kind);
        println!("  {kind:?}: acc {acc:.4}, worst oracle/executor gap {worst:.2e} (≤ 1e-4 ✓)");
        if kind == StrategyKind::PipelineAwareEma {
            final_acc = acc;
        }
    }

    let chance = 1.0 / classes as f32;
    if !smoke {
        assert!(final_acc > 1.5 * chance, "transformer did not learn: {final_acc}");
    }
    println!("\ntransformer_pipeline: OK (acc {final_acc:.4}, chance {chance:.2})");
}
