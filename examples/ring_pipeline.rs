//! Weight-ring replica parallelism, end to end: the same pipelined
//! training workload runs once per replica count on the in-process
//! weight ring (2D pipeline × data parallelism) and the final weights
//! are compared **bitwise** — the deterministic fixed-tree all-reduce
//! makes the result a pure function of the shard count, never of how
//! many threads the shard lanes are spread over.
//!
//!     cargo run --release --example ring_pipeline
//!     LAYERPIPE2_SMOKE=1 cargo run --release --example ring_pipeline   # CI smoke
//!
//! What it demonstrates:
//!   1. `train_ring` at N = 1, 2, 4 replicas over a fixed 4-shard batch
//!      decomposition produces bit-identical `final_weights`;
//!   2. the ring composes with pipelined strategies (pipeline-aware EMA
//!      here — each shard lane is a full delayed-gradient `Trainer`);
//!   3. throughput scales with replica threads (reported, not asserted:
//!      CI machines vary);
//!   4. the workload actually learns.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::teacher_dataset;
use layerpipe2::replica::{train_ring, RingConfig, RingReport};
use layerpipe2::strategy::StrategyKind;
use std::sync::Arc;

fn smoke() -> bool {
    std::env::var_os("LAYERPIPE2_SMOKE").is_some()
        || std::env::var_os("LAYERPIPE2_BENCH_SMOKE").is_some()
}

fn bitwise_eq(a: &RingReport, b: &RingReport) -> bool {
    a.final_weights.len() == b.final_weights.len()
        && a.final_weights
            .data()
            .iter()
            .zip(b.final_weights.data())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn main() {
    let smoke = smoke();
    if smoke {
        println!("[smoke mode: reduced samples and epochs]");
    }
    let (train_n, test_n, epochs) = if smoke { (192, 64, 2) } else { (768, 256, 6) };

    let backend: Backend = Arc::new(HostBackend::new());
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 32;
    cfg.model.input_dim = 24;
    cfg.model.hidden_dim = 48;
    cfg.model.classes = 4;
    cfg.model.layers = 4;
    cfg.pipeline.stages = 2;
    cfg.epochs = epochs;
    cfg.seed = 7;
    cfg.data = DataConfig {
        train_samples: train_n,
        test_samples: test_n,
        teacher_hidden: 24,
        label_noise: 0.0,
        seed: 1234,
    };
    cfg.validate().expect("config valid");
    let data = teacher_dataset(&cfg.model, &cfg.data);

    let shards = 4usize;
    let kind = StrategyKind::PipelineAwareEma;
    println!(
        "weight ring: {} shards over batch {}, strategy {}, {} epochs",
        shards,
        cfg.model.batch,
        kind.name(),
        cfg.epochs
    );

    let mut oracle: Option<RingReport> = None;
    for replicas in [1usize, 2, 4] {
        let ring = RingConfig::new(replicas, shards);
        let report =
            train_ring(&backend, &cfg, None, kind, &ring, &data).expect("ring training runs");
        let base = oracle.as_ref().map_or(report.samples_per_sec, |o| o.samples_per_sec);
        println!(
            "  replicas {}: {:>9.1} samples/s ({:.2}x)  train loss {:.4}  test acc {:.4}",
            replicas,
            report.samples_per_sec,
            report.samples_per_sec / base,
            report.train_loss,
            report.test_accuracy
        );
        match &oracle {
            None => oracle = Some(report),
            Some(o) => assert!(
                bitwise_eq(&report, o),
                "final weights at {replicas} replicas differ from the single-replica oracle"
            ),
        }
    }

    let oracle = oracle.expect("at least one run");
    let chance = 1.0 / cfg.model.classes as f32;
    if !smoke {
        assert!(
            oracle.test_accuracy > 1.5 * chance,
            "ring workload did not learn: {}",
            oracle.test_accuracy
        );
    }
    println!(
        "\nring_pipeline: OK (final weights bitwise identical across 1/2/4 replicas, acc {:.4}, chance {chance:.2})",
        oracle.test_accuracy
    );
}
