//! Fig. 2 substrate: delayed-LMS adaptation under increasing delay.
//!
//! Reproduces the qualitative content of the paper's DLMS foundation
//! (§III-A): delayed coefficient updates still converge for suitable
//! step sizes, convergence slows with delay, and the stability region
//! shrinks — the same phenomena the pipelined trainer exhibits at the
//! network scale.
//!
//! Run with: `cargo run --release --example dlms_delay_sweep`

use layerpipe2::dlms::{convergence_time, run, stable_mu_bound, DlmsConfig};

fn main() {
    println!("system identification: 16-tap FIR, white input, mu = 0.01\n");
    println!(
        "{:<8} {:>14} {:>14} {:>12} {:>10}",
        "delay M", "misalignment", "steady MSE", "conv@1e-3", "stable"
    );
    for delay in [0usize, 1, 2, 4, 8, 16, 32, 64] {
        let cfg = DlmsConfig { delay, mu: 0.01, ..Default::default() };
        let r = run(&cfg);
        let conv = convergence_time(&r.mse_curve, 1e-3)
            .map(|t| t.to_string())
            .unwrap_or_else(|| "never".into());
        println!(
            "{:<8} {:>14.3e} {:>14.3e} {:>12} {:>10}",
            delay, r.misalignment, r.steady_state_mse, conv, r.converged
        );
    }

    println!("\nstability boundary: largest stable mu shrinks with delay");
    println!("{:<8} {:>16} {:>18}", "delay M", "bound 2/(s^2(T+2M))", "diverges at 2x bound?");
    for delay in [0usize, 8, 32, 64] {
        let bound = stable_mu_bound(16, delay, 1.0);
        let hot = run(&DlmsConfig { delay, mu: 2.0 * bound, samples: 30_000, ..Default::default() });
        println!(
            "{:<8} {:>16.4} {:>18}",
            delay,
            bound,
            if hot.converged && hot.steady_state_mse < 1e-2 { "no" } else { "yes" }
        );
    }

    println!("\nsame effect at network scale: the pipelined trainer's gradient");
    println!("delay Delay(l) = 2S(l) obeys the identical tradeoff (see fig5).");
}
