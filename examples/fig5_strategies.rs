//! END-TO-END DRIVER (Fig. 5): full pipelined training of the 8-layer
//! MLP on the synthetic teacher workload, sweeping all five
//! weight-handling strategies and reporting the accuracy-vs-epoch curves
//! plus the memory-footprint comparison. The recorded run lives in
//! EXPERIMENTS.md.
//!
//! Run with:
//!   cargo run --release --example fig5_strategies            # full (30 epochs)
//!   cargo run --release --example fig5_strategies -- 8       # shorter
//!
//! All layers execute through AOT-compiled XLA artifacts whose matmuls
//! are the L1 Pallas kernel; Python is not involved at runtime.

use layerpipe2::config::ExperimentConfig;
use layerpipe2::coordinator::{check_fig5_shape, Coordinator};
use layerpipe2::metrics::write_csv;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(30);

    let mut cfg = match std::path::Path::new("configs/fig5.toml").exists() {
        true => ExperimentConfig::load("configs/fig5.toml")?,
        false => ExperimentConfig::default(),
    };
    cfg.epochs = epochs;
    cfg.csv_out = None; // we write it ourselves below

    let coordinator = Coordinator::new(cfg)?;
    let result = coordinator.sweep()?;

    // Accuracy curves, one row per epoch (the Fig. 5 series).
    println!("\nepoch-by-epoch test accuracy:");
    print!("{:>6}", "epoch");
    for c in &result.curves {
        print!("{:>14}", c.strategy);
    }
    println!();
    let max_epochs = result.curves.iter().map(|c| c.epochs.len()).max().unwrap_or(0);
    for e in 0..max_epochs {
        print!("{e:>6}");
        for c in &result.curves {
            match c.epochs.get(e) {
                Some(m) => print!("{:>14.4}", m.test_accuracy),
                None => print!("{:>14}", "-"),
            }
        }
        println!();
    }

    println!("\n{}", result.table());

    // Memory footprint: the O(L·S) → O(L) claim.
    println!("staleness-state memory (peak bytes):");
    for c in &result.curves {
        println!("  {:<16} {:>12}", c.strategy, c.peak_staleness_bytes());
    }

    write_csv("fig5_curves.csv", &result.curves)?;
    println!("\nwrote fig5_curves.csv");

    let problems = check_fig5_shape(&result);
    if problems.is_empty() {
        println!("fig5 shape: REPRODUCED — stashing tracks sequential, latest degrades,");
        println!("pipeline-aware EMA recovers stashing-level accuracy at O(L) memory.");
    } else {
        println!("fig5 shape deviations:");
        for p in &problems {
            println!("  - {p}");
        }
        std::process::exit(1);
    }
    Ok(())
}
