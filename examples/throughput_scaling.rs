//! Pipeline throughput scaling: the LayerPipe speedup claim on real
//! XLA compute.
//!
//! Runs the threaded stage pipeline (one OS thread per stage, bounded
//! channels) over the AOT-compiled forward artifacts and compares
//! batches/sec against single-threaded sequential execution, next to the
//! analytic schedule model's prediction.
//!
//! Run with: `cargo run --release --example throughput_scaling`
//! (requires `make artifacts` first).

use layerpipe2::model::Mlp;
use layerpipe2::pipeline::{forward_sequential, forward_throughput};
use layerpipe2::retiming::StagePartition;
use layerpipe2::runtime::Engine;
use layerpipe2::schedule::{evaluate, CostModel};
use layerpipe2::tensor::Tensor;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let engine = Arc::new(Engine::load("artifacts")?);
    let m = engine.manifest().model.clone();
    let cfg = layerpipe2::config::ModelConfig {
        batch: m.batch,
        input_dim: m.input_dim,
        hidden_dim: m.hidden_dim,
        classes: m.classes,
        layers: m.layers,
        init_scale: 1.0,
    };
    let mut rng = Rng::new(11);
    let mlp = Mlp::init(&cfg, &mut rng);
    let inputs: Vec<Tensor> =
        (0..8).map(|_| Tensor::randn(&[m.batch, m.input_dim], 1.0, &mut rng)).collect();

    let batches = 400;
    let seq = forward_sequential(&engine, &mlp, &inputs, batches)?;
    println!(
        "sequential: {:.0} batches/s ({} layers, batch {})",
        seq.batches_per_sec, m.layers, m.batch
    );

    println!(
        "\n{:<8} {:>14} {:>12} {:>16}",
        "stages", "batches/s", "speedup", "model prediction"
    );
    let cost = CostModel::uniform(m.layers);
    for k in [1usize, 2, 4, 8] {
        if k > m.layers {
            continue;
        }
        let p = StagePartition::even(m.layers, k)?;
        let r = forward_throughput(&engine, &mlp, &p, inputs.clone(), batches, 4)?;
        let predicted = evaluate(&p, &cost, batches as u64).speedup;
        println!(
            "{:<8} {:>14.0} {:>11.2}x {:>15.2}x",
            k,
            r.batches_per_sec,
            r.batches_per_sec / seq.batches_per_sec,
            predicted
        );
    }
    println!("\n(threaded speedup saturates below the analytic bound once per-exec");
    println!(" XLA dispatch overhead dominates the tiny per-stage compute — see");
    println!(" EXPERIMENTS.md §THRU for the paper-scale reading)");
    Ok(())
}
