//! Pipeline throughput scaling: the LayerPipe speedup claim on real
//! compute.
//!
//! Runs the threaded stage pipeline (one OS thread per stage, bounded
//! channels) over the selected backend — AOT-compiled PJRT artifacts
//! when present, the pure-Rust host backend otherwise — and compares
//! batches/sec against single-threaded sequential execution, next to the
//! analytic schedule model's prediction.
//!
//! Run with: `cargo run --release --example throughput_scaling`
//! (no artifacts required; set `LAYERPIPE2_BACKEND=pjrt` to force the
//! artifact path on a `--features pjrt` build).

use layerpipe2::backend::{self, Exec};
use layerpipe2::model::Mlp;
use layerpipe2::pipeline::{forward_sequential, forward_throughput};
use layerpipe2::retiming::StagePartition;
use layerpipe2::runtime::Manifest;
use layerpipe2::schedule::{evaluate, CostModel};
use layerpipe2::tensor::Tensor;
use layerpipe2::util::Rng;

fn main() -> anyhow::Result<()> {
    let backend = backend::from_env("artifacts")?;
    let cfg = Manifest::model_config_or_default("artifacts");
    let mut rng = Rng::new(11);
    let mlp = Mlp::init(&cfg, &mut rng);
    let inputs: Vec<Tensor> =
        (0..8).map(|_| Tensor::randn(&[cfg.batch, cfg.input_dim], 1.0, &mut rng)).collect();

    let batches = 400;
    let seq = forward_sequential(&backend, &mlp, &inputs, batches)?;
    println!(
        "sequential: {:.0} batches/s ({} layers, batch {}, backend {})",
        seq.batches_per_sec,
        cfg.layers,
        cfg.batch,
        backend.name()
    );

    println!(
        "\n{:<8} {:>14} {:>12} {:>16}",
        "stages", "batches/s", "speedup", "model prediction"
    );
    let cost = CostModel::uniform(cfg.layers);
    for k in [1usize, 2, 4, 8] {
        if k > cfg.layers {
            continue;
        }
        let p = StagePartition::even(cfg.layers, k)?;
        let r = forward_throughput(&backend, &mlp, &p, inputs.clone(), batches, 4)?;
        let predicted = evaluate(&p, &cost, batches as u64).speedup;
        println!(
            "{:<8} {:>14.0} {:>11.2}x {:>15.2}x",
            k,
            r.batches_per_sec,
            r.batches_per_sec / seq.batches_per_sec,
            predicted
        );
    }
    println!("\n(threaded speedup saturates below the analytic bound once per-exec");
    println!(" dispatch overhead dominates the tiny per-stage compute — the gap");
    println!(" shrinks as layer compute grows)");
    Ok(())
}
