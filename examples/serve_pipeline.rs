//! Batched inference serving, end to end: train a network, serve it to
//! concurrent clients through the staged forward pipeline, hot-reload a
//! newer checkpoint mid-traffic, and prove every response bitwise-equal
//! to the single-threaded sequential oracle of the exact weight version
//! that produced it.
//!
//!     cargo run --release --example serve_pipeline
//!     LAYERPIPE2_SMOKE=1 cargo run --release --example serve_pipeline   # CI smoke
//!
//! What it demonstrates (the ROADMAP serving pillar):
//!   1. a **dense** MLP and a **conv+pool+dense** CNN, both *trained*
//!      first (the paper's training pipeline) and then served;
//!   2. multi-client batched serving ≡ `Network::forward_full` bitwise,
//!      for every response, under real concurrency;
//!   3. atomic hot-reload: traffic in flight across a weight swap is
//!      attributable to exactly one epoch — no torn versions;
//!   4. a checkpoint saved to disk and reloaded via
//!      `Server::reload_from_file` serves bitwise-identically to the
//!      in-memory network it came from.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::{image_teacher_dataset, teacher_dataset, Splits};
use layerpipe2::layers::{Feature, LayerSpec, Network, NetworkSpec};
use layerpipe2::model::checkpoint;
use layerpipe2::serving::{Server, ServerConfig};
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::Tensor;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn smoke() -> bool {
    std::env::var_os("LAYERPIPE2_SMOKE").is_some()
        || std::env::var_os("LAYERPIPE2_BENCH_SMOKE").is_some()
}

fn backend() -> Backend {
    Arc::new(HostBackend::new())
}

/// Train `spec` briefly and return the learned network.
fn train_network(cfg: &ExperimentConfig, spec: &NetworkSpec, data: &Splits) -> Network {
    let mut rng = Rng::new(cfg.seed);
    let mut t = Trainer::with_spec(backend(), cfg, spec, StrategyKind::PipelineAwareEma, &mut rng)
        .expect("trainer init");
    let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
    let curve = t.train(data, &mut batch_rng).expect("training");
    println!("  trained: final acc {:.4}", curve.final_accuracy());
    t.net.snapshot().expect("snapshot")
}

/// Serve `versions[0]`, hot-reload the later versions mid-traffic, and
/// verify every response bitwise against the per-version oracle.
fn serve_and_verify(name: &str, versions: &[Network], clients: usize, per_client: usize) {
    let in_dim = versions[0].input_dim();
    let cfg = ServerConfig {
        max_batch: 8,
        max_wait_ticks: 2,
        queue_depth: 32,
        stages: 2,
        ..ServerConfig::default()
    };
    let server = Server::start(backend(), &versions[0], &cfg).expect("server start");
    println!(
        "  serving {name}: stages {:?}, {clients} clients x {per_client} requests",
        server.partition().stage_of()
    );

    // Distinct inputs + the sequential oracle per weight version.
    let mut rng = Rng::new(77);
    let inputs: Vec<Tensor> =
        (0..12).map(|i| Tensor::randn(&[1 + i % cfg.max_batch.min(4), in_dim], 1.0, &mut rng)).collect();
    let be = HostBackend::new();
    let expected: Vec<Vec<Tensor>> = versions
        .iter()
        .map(|v| {
            let mut o = v.snapshot().expect("oracle snapshot");
            inputs.iter().map(|x| o.forward_full(&be, x).expect("oracle fwd")).collect()
        })
        .collect();

    std::thread::scope(|s| {
        let inputs = &inputs;
        let expected = &expected;
        for c in 0..clients {
            let mut cl = server.client();
            s.spawn(move || {
                // Strict submit→receive lockstep (window 0) so reloads
                // interleave the traffic as finely as possible; the
                // harness asserts FIFO order, known + non-decreasing
                // epochs, and bitwise equality with that epoch's oracle.
                let pick = |i: usize| (c + 5 * i) % inputs.len();
                layerpipe2::serving::drive_and_verify(&mut cl, inputs, expected, pick, per_client, 0)
                    .unwrap_or_else(|e| panic!("client {c}: {e:#}"));
            });
        }
        // Swap in the newer versions while the clients hammer the queue.
        for v in versions.iter().skip(1) {
            std::thread::sleep(std::time::Duration::from_millis(2));
            server.reload(v).expect("hot reload");
        }
    });

    // Post-reload traffic must see the final epoch.
    let final_epoch = (versions.len() - 1) as u64;
    let mut cl = server.client();
    cl.submit(inputs[0].clone()).expect("submit");
    let r = cl.recv().expect("recv");
    assert_eq!(r.version, final_epoch, "post-reload batch must serve the newest weights");
    assert_eq!(r.data, expected[final_epoch as usize][0]);

    let lat = server.latency_ms();
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.completed, (clients * per_client) as u64 + 1);
    assert_eq!(stats.dropped, 0);
    print!(
        "  OK: {} responses over {} batches (occupancy {:.2}), {} reload(s)",
        stats.completed, stats.batches, stats.occupancy, stats.reloads
    );
    if let Some((p50, p99)) = lat {
        print!(", batch latency p50 {p50:.3}ms p99 {p99:.3}ms");
    }
    println!();
}

/// Disk roundtrip: a checkpoint written from `net` and hot-reloaded from
/// the file must serve bitwise like `net` itself.
fn checkpoint_roundtrip(net: &Network, in_dim: usize) {
    let cfg = ServerConfig {
        max_batch: 4,
        max_wait_ticks: 0,
        queue_depth: 8,
        stages: 2,
        ..ServerConfig::default()
    };
    // Start from *different* weights so the reload is observable.
    let spec = NetworkSpec {
        input: net.input.clone(),
        layers: net.layers.iter().map(|nl| nl.spec.clone()).collect(),
        init_scale: net.init_scale,
    };
    let other = Network::build(&spec, &mut Rng::new(999)).expect("other net");
    let server = Server::start(backend(), &other, &cfg).expect("server start");

    let path = std::env::temp_dir().join(format!("lp2_serve_{}.bin", std::process::id()));
    let path = path.to_str().expect("temp path").to_string();
    checkpoint::save_network(net, &path).expect("save checkpoint");
    let epoch = server.reload_from_file(&path).expect("reload from disk");
    std::fs::remove_file(&path).ok();

    let x = Tensor::randn(&[3, in_dim], 1.0, &mut Rng::new(13));
    let mut cl = server.client();
    cl.submit(x.clone()).expect("submit");
    let r = cl.recv().expect("recv");
    let mut oracle = net.snapshot().expect("oracle");
    assert_eq!(r.version, epoch);
    assert_eq!(
        r.data,
        oracle.forward_full(&HostBackend::new(), &x).expect("oracle fwd"),
        "disk-roundtripped weights must serve bitwise-identically"
    );
    server.shutdown().expect("shutdown");
    println!("  OK: restore-from-disk serves bitwise-identically (epoch {epoch})");
}

fn main() {
    let smoke = smoke();
    if smoke {
        println!("[smoke mode: reduced samples, epochs and traffic]");
    }
    let (train_n, test_n, epochs) = if smoke { (96, 48, 1) } else { (384, 128, 4) };
    let (clients, per_client) = if smoke { (3, 16) } else { (4, 64) };

    // ---------------- dense MLP: train two versions, serve with reload --
    println!("\n=== dense MLP serving ===");
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 16;
    cfg.model.input_dim = 24;
    cfg.model.hidden_dim = 24;
    cfg.model.classes = 4;
    cfg.model.layers = 4;
    cfg.pipeline.stages = 2;
    cfg.epochs = epochs;
    cfg.seed = 7;
    cfg.data = DataConfig {
        train_samples: train_n,
        test_samples: test_n,
        teacher_hidden: 16,
        label_noise: 0.0,
        seed: 1234,
    };
    let dense_spec = NetworkSpec::mlp(&cfg.model);
    let data = teacher_dataset(&cfg.model, &cfg.data);
    let v0 = train_network(&cfg, &dense_spec, &data);
    // A second, longer-trained version to hot-reload mid-traffic.
    let mut cfg2 = cfg.clone();
    cfg2.epochs = epochs + 1;
    cfg2.seed = 8;
    let v1 = train_network(&cfg2, &dense_spec, &data);
    serve_and_verify("dense", &[v0, v1], clients, per_client);

    // ---------------- conv stack: train, serve, disk roundtrip ----------
    println!("\n=== conv+pool+dense serving ===");
    let (h, w, c, classes) = (8usize, 8usize, 1usize, 4usize);
    let conv_spec = NetworkSpec {
        input: Feature::Image { h, w, c },
        layers: vec![
            LayerSpec::Conv2d { out_c: 4, k: 3, stride: 1, pad: 1, relu: true },
            LayerSpec::MaxPool2d { k: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 24, relu: true },
            LayerSpec::Dense { units: classes, relu: false },
        ],
        init_scale: 1.0,
    };
    let mut ccfg = ExperimentConfig::default();
    ccfg.model.batch = 16;
    ccfg.model.input_dim = h * w * c;
    ccfg.model.classes = classes;
    ccfg.model.layers = conv_spec.layers.len();
    ccfg.model.hidden_dim = 24;
    ccfg.pipeline.stages = 2;
    ccfg.epochs = epochs;
    ccfg.seed = 11;
    ccfg.data = DataConfig {
        train_samples: train_n,
        test_samples: test_n,
        teacher_hidden: 16,
        label_noise: 0.0,
        seed: 4321,
    };
    let cdata = image_teacher_dataset(h, w, c, classes, &ccfg.data);
    let cnet = train_network(&ccfg, &conv_spec, &cdata);
    let cnet2 = {
        let mut c2 = ccfg.clone();
        c2.seed = 12;
        train_network(&c2, &conv_spec, &cdata)
    };
    checkpoint_roundtrip(&cnet, h * w * c);
    serve_and_verify("conv", &[cnet, cnet2], clients, per_client);

    println!("\nserve_pipeline: OK (batched serving bitwise == sequential oracle, hot-reload atomic)");
}
