//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the AOT artifacts, derives the pipeline's delay structure via
//! retiming, trains the proposed pipeline-aware EMA strategy for a few
//! epochs against the sequential reference, and prints the comparison.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first).

use layerpipe2::config::ExperimentConfig;
use layerpipe2::coordinator::Coordinator;
use layerpipe2::retiming::Derivation;
use layerpipe2::strategy::StrategyKind;

fn main() -> anyhow::Result<()> {
    // 1. The delay structure the paper derives (Eq. 1): per-layer
    //    pipelining of an 8-layer network.
    let stage_of: Vec<usize> = (0..8).collect();
    let derivation = Derivation::derive(8, &stage_of)?;
    derivation.verify()?;
    println!("gradient delays Delay(l) = 2·S(l): {:?}", derivation.gradient_delay);

    // 2. A short training comparison: sequential vs the proposed
    //    pipeline-aware EMA reconstruction (no weight stashing).
    let mut cfg = ExperimentConfig::default();
    cfg.epochs = 5;
    cfg.pipeline.warmup_epochs = 1;
    cfg.strategies = vec![StrategyKind::Sequential, StrategyKind::PipelineAwareEma];

    let coordinator = Coordinator::new(cfg)?;
    let result = coordinator.sweep()?;
    println!("\n{}", result.table());

    let seq = result.curve(StrategyKind::Sequential).expect("sequential ran");
    let ema = result.curve(StrategyKind::PipelineAwareEma).expect("ema ran");
    println!(
        "pipeline-aware EMA reaches {:.1}% of the sequential accuracy with {} B of staleness state",
        100.0 * ema.final_accuracy() / seq.final_accuracy().max(1e-6),
        ema.peak_staleness_bytes(),
    );
    Ok(())
}
