//! Heterogeneous pipelined training, end to end: a conv+pool+dense CNN
//! on image-shaped teacher data and a dense+LIF spiking net, both
//! executed by the multi-threaded `PipelinedTrainer` with stage
//! boundaries chosen by **cost-balanced compute** (LayerPipe) and
//! checked batch-for-batch against the iteration-indexed `Trainer`
//! oracle.
//!
//!     cargo run --release --example conv_pipeline
//!     LAYERPIPE2_SMOKE=1 cargo run --release --example conv_pipeline   # CI smoke
//!
//! What it demonstrates (the paper's abstract scope — "convolutional,
//! fully connected, and spiking neural networks"):
//!   1. cost reports per layer and the balanced partition they induce;
//!   2. gradient delays still follow `d = 2·S(l)` (downstream stages);
//!   3. threaded execution ≡ the oracle (loss curves within 1e-4) for
//!      the paper's proposed pipeline-aware EMA strategy;
//!   4. both workloads actually learn.

use layerpipe2::backend::{Backend, HostBackend};
use layerpipe2::config::{DataConfig, ExperimentConfig};
use layerpipe2::data::{image_teacher_dataset, teacher_dataset, Splits};
use layerpipe2::layers::{Feature, LayerSpec, Network, NetworkSpec};
use layerpipe2::pipeline::PipelinedTrainer;
use layerpipe2::strategy::StrategyKind;
use layerpipe2::train::Trainer;
use layerpipe2::util::Rng;
use std::sync::Arc;

fn smoke() -> bool {
    std::env::var_os("LAYERPIPE2_SMOKE").is_some()
        || std::env::var_os("LAYERPIPE2_BENCH_SMOKE").is_some()
}

fn backend() -> Backend {
    Arc::new(HostBackend::new())
}

/// Run one heterogeneous workload on both engines and report.
fn run_workload(
    name: &str,
    cfg: &ExperimentConfig,
    spec: &NetworkSpec,
    data: &Splits,
    kind: StrategyKind,
) -> (f32, f32) {
    // Show the cost model and the partition it induces.
    let net = Network::build(spec, &mut Rng::new(cfg.seed)).expect("spec builds");
    let costs: Vec<u64> = net.costs(cfg.model.batch).iter().map(|c| c.total_flops()).collect();
    println!("\n=== {name} ({} layers, {} stages) ===", net.num_layers(), cfg.pipeline.stages);
    for (l, nl) in net.layers.iter().enumerate() {
        println!("  layer {l}: {:<40} {:>12} flop/iter", nl.op.name(), costs[l]);
    }

    let oracle = {
        let mut rng = Rng::new(cfg.seed);
        let mut t = Trainer::with_spec(backend(), cfg, spec, kind, &mut rng).expect("oracle init");
        println!(
            "  partition (cost-balanced): {:?}  delays: {:?}",
            t.partition().stage_of(),
            t.gradient_delays()
        );
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        t.train(data, &mut batch_rng).expect("oracle train")
    };
    let threaded = {
        let mut rng = Rng::new(cfg.seed);
        let mut ex =
            PipelinedTrainer::with_spec(backend(), cfg, spec, kind, &mut rng).expect("executor init");
        let mut batch_rng = Rng::new(cfg.seed ^ 0x5EED_BA7C);
        ex.train(data, &mut batch_rng).expect("executor train")
    };

    // The acceptance bar: threaded ≡ oracle within 1e-4, epoch by epoch.
    let mut worst = 0.0f32;
    for (a, b) in oracle.epochs.iter().zip(&threaded.epochs) {
        assert_eq!(
            a.train_loss.is_nan(),
            b.train_loss.is_nan(),
            "{name}: NaN pattern mismatch between engines"
        );
        if !a.train_loss.is_nan() {
            worst = worst.max((a.train_loss - b.train_loss).abs());
        }
        worst = worst.max((a.test_accuracy - b.test_accuracy).abs());
    }
    assert!(
        worst <= 1e-4,
        "{name}: threaded executor diverged from oracle (worst gap {worst})"
    );
    println!(
        "  oracle acc {:.4} | threaded acc {:.4} | worst oracle/executor gap {:.2e} (≤ 1e-4 ✓)",
        oracle.final_accuracy(),
        threaded.final_accuracy(),
        worst
    );
    (oracle.final_accuracy(), threaded.final_accuracy())
}

fn main() {
    let smoke = smoke();
    if smoke {
        println!("[smoke mode: reduced samples and epochs]");
    }
    let (train_n, test_n, epochs) = if smoke { (128, 64, 2) } else { (512, 256, 6) };

    // ---------------- CNN: conv + pool + conv + flatten + dense ----------
    let (h, w, c, classes) = (8usize, 8usize, 1usize, 4usize);
    let conv_spec = NetworkSpec {
        input: Feature::Image { h, w, c },
        layers: vec![
            LayerSpec::Conv2d { out_c: 4, k: 3, stride: 1, pad: 1, relu: true },
            LayerSpec::MaxPool2d { k: 2, stride: 2 },
            LayerSpec::Conv2d { out_c: 8, k: 3, stride: 1, pad: 1, relu: true },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 32, relu: true },
            LayerSpec::Dense { units: classes, relu: false },
        ],
        init_scale: 1.0,
    };
    let mut cfg = ExperimentConfig::default();
    cfg.model.batch = 16;
    cfg.model.input_dim = h * w * c;
    cfg.model.classes = classes;
    cfg.model.layers = conv_spec.layers.len();
    cfg.model.hidden_dim = 32; // informational for this spec
    cfg.pipeline.stages = 3;
    cfg.epochs = epochs;
    cfg.seed = 7;
    cfg.data = DataConfig {
        train_samples: train_n,
        test_samples: test_n,
        teacher_hidden: 24,
        label_noise: 0.0,
        seed: 1234,
    };
    let image_data = image_teacher_dataset(h, w, c, classes, &cfg.data);
    let (conv_acc, _) = run_workload(
        "conv+pool+dense CNN",
        &cfg,
        &conv_spec,
        &image_data,
        StrategyKind::PipelineAwareEma,
    );

    // ---------------- SNN: dense synapses + LIF spiking activations ------
    let in_dim = 32usize;
    let snn_spec = NetworkSpec {
        input: Feature::Flat(in_dim),
        layers: vec![
            LayerSpec::Dense { units: 48, relu: false }, // membrane potential
            LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },    // spikes + surrogate grad
            LayerSpec::Dense { units: 48, relu: false },
            LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            LayerSpec::Dense { units: classes, relu: false }, // logits
        ],
        init_scale: 1.0,
    };
    let mut snn_cfg = ExperimentConfig::default();
    snn_cfg.model.batch = 16;
    snn_cfg.model.input_dim = in_dim;
    snn_cfg.model.classes = classes;
    snn_cfg.model.layers = snn_spec.layers.len();
    snn_cfg.model.hidden_dim = 48;
    snn_cfg.pipeline.stages = 3;
    snn_cfg.epochs = epochs;
    snn_cfg.seed = 11;
    snn_cfg.data = DataConfig {
        train_samples: train_n,
        test_samples: test_n,
        teacher_hidden: 24,
        label_noise: 0.0,
        seed: 4321,
    };
    let snn_data = teacher_dataset(&snn_cfg.model, &snn_cfg.data);
    let (snn_acc, _) = run_workload(
        "dense+LIF spiking net",
        &snn_cfg,
        &snn_spec,
        &snn_data,
        StrategyKind::PipelineAwareEma,
    );

    let chance = 1.0 / classes as f32;
    if !smoke {
        assert!(conv_acc > 1.5 * chance, "CNN did not learn: {conv_acc}");
        assert!(snn_acc > chance, "SNN below chance: {snn_acc}");
    }
    println!("\nconv_pipeline: OK (cnn acc {conv_acc:.4}, snn acc {snn_acc:.4}, chance {chance:.2})");
}
