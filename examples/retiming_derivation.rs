//! Figs. 3 & 4: the retiming derivation, printed step by step.
//!
//! Shows (a) the per-layer pipeline construction — delay insertion at
//! feedforward cutsets + DLMS gradient edges, then retiming to stage
//! boundaries — and (b) the grouped two-layer-stage variant, verifying
//! the paper's claims: `Delay(l) = 2·S(l)`, identical delays within a
//! group, and stashing emerging as edge delays.
//!
//! Run with: `cargo run --release --example retiming_derivation`

use layerpipe2::graph::{Dfg, EdgeKind, NodeKind};
use layerpipe2::retiming::{
    closed_form_lags, delay_formula, insert_pipeline_delays, Derivation, StagePartition,
};

fn show(partition: &StagePartition, title: &str) -> anyhow::Result<()> {
    println!("\n=== {title} ===");
    println!("stage_of = {:?}", partition.stage_of());

    // Step 0: the sequential graph has a zero-delay gradient loop.
    let g0 = Dfg::backprop(partition.layers(), partition.stage_of());
    println!(
        "sequential graph: min cycle delay = {:?} (zero ⇒ retiming alone cannot pipeline)",
        g0.min_cycle_delay()
    );

    // Step 1-2: insert delays (feedforward cutsets + DLMS gradient edges).
    let mut g1 = g0.clone();
    insert_pipeline_delays(&mut g1);
    let inserted: i64 = g1.edges.iter().map(|e| e.delay).sum::<i64>()
        - g0.edges.iter().map(|e| e.delay).sum::<i64>();
    println!("inserted {inserted} delay elements (input/output cutsets + 2S(l) per gradient edge)");

    // Step 3-4: retime (closed form == the recursive compaction).
    let retimed = closed_form_lags(&g1).apply(&g1)?;
    println!("after retiming, per-layer state:");
    println!(
        "{:<6} {:>6} {:>10} {:>10} {:>10} {:>9}",
        "layer", "stage", "Delay(l)", "act-stash", "wt-stash", "2S(l)"
    );
    let formula = delay_formula(partition.stage_of());
    for l in 0..partition.layers() {
        let act = retimed
            .edge_delay(NodeKind::Forward(l), NodeKind::WeightGrad(l))
            .unwrap();
        let wst = retimed
            .edge_delay(NodeKind::Weight(l), NodeKind::ActGrad(l))
            .unwrap();
        println!(
            "{:<6} {:>6} {:>10} {:>10} {:>10} {:>9}",
            l,
            partition.stage_of()[l],
            formula[l],
            act,
            wst,
            2 * partition.downstream_stages(l)
        );
    }

    // Full verification (closed form, stepwise equivalence, legality).
    let d = Derivation::derive(partition.layers(), partition.stage_of())?;
    d.verify()?;
    let s = Derivation::derive_stepwise(partition.layers(), partition.stage_of())?;
    assert_eq!(d.gradient_delay, s.gradient_delay);
    println!("verified: Eq. 1 holds; iterative cutset moves == closed-form retiming");

    // Boundary edges carry exactly one delay each way.
    let boundaries = retimed
        .edges
        .iter()
        .filter(|e| {
            matches!(e.kind, EdgeKind::Activation | EdgeKind::GradFlow) && e.delay > 0
        })
        .count();
    println!("stage-boundary delay elements (fwd+bwd): {boundaries}");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // Fig. 3: one stage per layer.
    show(&StagePartition::even(4, 4)?, "Fig. 3 — per-layer pipelining (L=4)")?;
    // Fig. 4: two-layer groups.
    show(&StagePartition::from_group_sizes(&[2, 2])?, "Fig. 4 — grouped stages (2+2)")?;
    // Deeper multistage mix.
    show(
        &StagePartition::from_group_sizes(&[3, 2, 2, 1])?,
        "multistage generalization (3+2+2+1)",
    )?;
    Ok(())
}
