#!/usr/bin/env bash
# Tier-1 verification gate + lint. Run from anywhere; no artifacts, no
# network, and no PJRT toolchain required — the default feature set is
# fully self-contained (vendored anyhow, host backend).
#
#   scripts/verify.sh            # build + test + clippy
#   scripts/verify.sh --pjrt     # additionally verify the pjrt feature
#                                # (needs the xla dep enabled in Cargo.toml)
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo clippy -- -D warnings"
cargo clippy -- -D warnings

if [[ "${1:-}" == "--pjrt" ]]; then
    echo "==> cargo build --release --features pjrt"
    cargo build --release --features pjrt
    echo "==> cargo test -q --features pjrt"
    cargo test -q --features pjrt
fi

echo "verify: OK"
