#!/usr/bin/env bash
# Tier-1 verification gate + lint. Run from anywhere; no artifacts, no
# network, and no PJRT toolchain required — the default feature set is
# fully self-contained (vendored anyhow, host backend).
#
#   scripts/verify.sh            # build + test + clippy
#   scripts/verify.sh --pjrt     # additionally verify the pjrt feature
#                                # (needs the xla dep enabled in Cargo.toml)
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo clippy -- -D warnings -D clippy::perf"
cargo clippy -- -D warnings -D clippy::perf

# Release-mode bench smoke: runs the hot-path bench with reduced samples
# so kernel/allocation regressions fail the gate (and refreshes
# BENCH_hotpath.json + BENCH_layers.json + BENCH_kernels.json +
# BENCH_serving.json + BENCH_ring.json — the dense, layer-zoo,
# kernel-family, serving and replica-ring machine-readable perf
# trajectories). The kernel-family section validates every kernel
# in-run: shape mismatches, NaN/non-finite outputs, packed-vs-reference
# bit drift and tree-reduction worker instability all abort the bench
# and therefore fail this gate; the serving section verifies every
# response bitwise against the sequential forward oracle; the ring
# section verifies every replica count's final weights bitwise against
# the single-replica oracle.
echo "==> bench smoke (release, reduced samples)"
LAYERPIPE2_BENCH_SMOKE=1 cargo bench --bench runtime_hotpath
test -s BENCH_kernels.json || { echo "verify: BENCH_kernels.json missing or empty"; exit 1; }
# The mixed-precision section (f32 vs bf16 storage kernels) must have
# run and recorded its rows — it carries the in-run widening-on-pack
# bitwise gate and the dtype-derived error bound vs the f32 oracle.
grep -q '"mixed_precision"' BENCH_kernels.json \
    || { echo "verify: BENCH_kernels.json lacks the mixed_precision section"; exit 1; }
test -s BENCH_layers.json || { echo "verify: BENCH_layers.json missing or empty"; exit 1; }
# The HOTPATH-k attention section must have run and recorded its rows —
# it validates every forward/backward output finite in-run (the masked
# softmax total-function contract under causal masking).
grep -q '"attention"' BENCH_layers.json \
    || { echo "verify: BENCH_layers.json lacks the attention section"; exit 1; }
test -s BENCH_serving.json || { echo "verify: BENCH_serving.json missing or empty"; exit 1; }
# The AIMD adaptive-batching section must have run (it carries the in-run
# bitwise-oracle gate with the controller enabled and the clamp check on
# the final limits).
grep -q '"adaptive"' BENCH_serving.json \
    || { echo "verify: BENCH_serving.json lacks the adaptive section"; exit 1; }
test -s BENCH_ring.json || { echo "verify: BENCH_ring.json missing or empty"; exit 1; }
# Observability overhead gate: the HOTPATH-j section must have run and
# the span-gated dense hot path must stay within 2% of the obs-off
# baseline (gate_ok is computed in-run by the bench).
test -s BENCH_observability.json \
    || { echo "verify: BENCH_observability.json missing or empty"; exit 1; }
grep -q '"gate_ok":true' BENCH_observability.json \
    || { echo "verify: observability overhead gate failed (see BENCH_observability.json)"; exit 1; }

# Heterogeneous end-to-end smoke: conv+pool+dense and dense+LIF stacks
# through the threaded executor with cost-balanced stages, asserting
# oracle equivalence ≤ 1e-4 (the layers-PR acceptance bar).
echo "==> conv pipeline example (smoke)"
LAYERPIPE2_SMOKE=1 cargo run --release --example conv_pipeline

# Transformer end-to-end smoke: Embedding → [SelfAttention → LayerNorm
# → Dense] × 2 on token-teacher data through the threaded executor with
# cost-balanced stages, asserting oracle equivalence ≤ 1e-4 for all
# five weight-version strategies.
echo "==> transformer pipeline example (smoke)"
LAYERPIPE2_SMOKE=1 cargo run --release --example transformer_pipeline

# Serving end-to-end smoke: trained dense + conv networks through the
# multi-client batched server with a mid-traffic hot reload and a
# restore-from-disk roundtrip, every response asserted bitwise equal to
# the sequential forward oracle of the epoch that served it.
echo "==> serve pipeline example (smoke)"
LAYERPIPE2_SMOKE=1 cargo run --release --example serve_pipeline

# Chaos/soak smoke: the deterministic fault-injection harness — client
# churn, slow/dead clients, reload storms, admission saturation and
# stage-worker stalls — asserting zero lost/duplicated/reordered
# accepted responses with every payload bitwise equal to its epoch's
# oracle, and merging the accounting into BENCH_serving.json under
# "soak" (the bench smoke above rewrites that file, so the soak gate
# must run after it).
echo "==> serving chaos soak (smoke)"
cargo run --release -- soak --smoke
grep -q '"soak"' BENCH_serving.json \
    || { echo "verify: BENCH_serving.json lacks the soak section"; exit 1; }
grep -q '"lost":0' BENCH_serving.json \
    || { echo "verify: soak reported lost responses"; exit 1; }
grep -q '"duplicated":0' BENCH_serving.json \
    || { echo "verify: soak reported duplicated responses"; exit 1; }

# Replica-ring end-to-end smoke: the same pipelined workload trained at
# 1, 2 and 4 replicas over a fixed shard decomposition, final weights
# asserted bitwise identical across counts (the deterministic
# all-reduce contract).
echo "==> ring pipeline example (smoke)"
LAYERPIPE2_SMOKE=1 cargo run --release --example ring_pipeline

if [[ "${1:-}" == "--pjrt" ]]; then
    echo "==> cargo build --release --features pjrt"
    cargo build --release --features pjrt
    echo "==> cargo test -q --features pjrt"
    cargo test -q --features pjrt
fi

echo "verify: OK"
