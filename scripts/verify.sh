#!/usr/bin/env bash
# Tier-1 verification gate + lint. Run from anywhere; no artifacts, no
# network, and no PJRT toolchain required — the default feature set is
# fully self-contained (vendored anyhow, host backend).
#
#   scripts/verify.sh            # build + test + clippy
#   scripts/verify.sh --pjrt     # additionally verify the pjrt feature
#                                # (needs the xla dep enabled in Cargo.toml)
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo clippy -- -D warnings -D clippy::perf"
cargo clippy -- -D warnings -D clippy::perf

# Release-mode bench smoke: runs the hot-path bench with reduced samples
# so kernel/allocation regressions fail the gate (and refreshes
# BENCH_hotpath.json + BENCH_layers.json + BENCH_kernels.json — the
# dense, layer-zoo and kernel-family machine-readable perf
# trajectories). The kernel-family section validates every kernel
# in-run: shape mismatches, NaN/non-finite outputs, packed-vs-reference
# bit drift and tree-reduction worker instability all abort the bench
# and therefore fail this gate.
echo "==> bench smoke (release, reduced samples)"
LAYERPIPE2_BENCH_SMOKE=1 cargo bench --bench runtime_hotpath
test -s BENCH_kernels.json || { echo "verify: BENCH_kernels.json missing or empty"; exit 1; }

# Heterogeneous end-to-end smoke: conv+pool+dense and dense+LIF stacks
# through the threaded executor with cost-balanced stages, asserting
# oracle equivalence ≤ 1e-4 (the layers-PR acceptance bar).
echo "==> conv pipeline example (smoke)"
LAYERPIPE2_SMOKE=1 cargo run --release --example conv_pipeline

if [[ "${1:-}" == "--pjrt" ]]; then
    echo "==> cargo build --release --features pjrt"
    cargo build --release --features pjrt
    echo "==> cargo test -q --features pjrt"
    cargo test -q --features pjrt
fi

echo "verify: OK"
